"""Model-stack tests: per-arch smoke (deliverable f), SSD-vs-recurrence
oracle, MoE impl consistency, attention blockwise-vs-naive, decode-vs-
prefill cache equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config
from repro.models import transformer as tf
from repro.models import cnn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.configs.base import LayerSpec, ModelConfig, uniform_pattern


def make_batch(cfg, b=2, s=32, seed=0):
    key = jax.random.PRNGKey(seed)
    if cfg.frontend == "audio_codebooks":
        toks = jax.random.randint(key, (b, cfg.n_codebooks, s), 0, cfg.vocab_size)
        return {"tokens": toks, "labels": toks}
    if cfg.frontend == "vision_stub":
        toks = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
        pe = jax.random.normal(key, (b, cfg.n_patches, cfg.d_vision), jnp.float32)
        return {"tokens": toks, "labels": toks, "patch_embeds": pe}
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    return {"tokens": toks, "labels": toks}


# ---------------------------------------------------------------------------
# (f) per-architecture smoke: reduced config, one forward + one train step
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_arch_smoke_forward_and_train_step(arch):
    cfg = get_config(arch, reduced=True)
    assert cfg.d_model <= 512 and (not cfg.n_experts or cfg.n_experts <= 4)
    assert len(cfg.layers) <= 2
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg)

    loss, grads = jax.jit(jax.value_and_grad(lambda p: tf.lm_loss(p, cfg, batch)))(params)
    assert np.isfinite(float(loss)), arch
    for leaf in jax.tree.leaves(grads):
        assert np.all(np.isfinite(np.asarray(leaf, np.float32))), arch

    # one SGD step moves the loss
    new_params = jax.tree.map(
        lambda p, g: (p.astype(jnp.float32) - 0.1 * g.astype(jnp.float32)).astype(p.dtype),
        params, grads,
    )
    loss2 = float(tf.lm_loss(new_params, cfg, batch))
    assert np.isfinite(loss2)


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_arch_smoke_decode_shapes(arch):
    cfg = get_config(arch, reduced=True)
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    b = 2
    caches = tf.init_caches(cfg, b, 16)
    batch = make_batch(cfg, b=b, s=1)
    batch.pop("labels")
    if cfg.frontend == "vision_stub":
        batch["patch_embeds"] = batch["patch_embeds"][:, :0]
    logits, new_caches = tf.decode_step(params, cfg, batch, jnp.asarray(0, jnp.int32), caches)
    if cfg.frontend == "audio_codebooks":
        assert logits.shape == (b, 1, cfg.n_codebooks, cfg.vocab_size)
    else:
        assert logits.shape == (b, 1, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    assert jax.tree.structure(new_caches) == jax.tree.structure(caches)


# ---------------------------------------------------------------------------
# SSD chunked scan vs naive recurrence oracle
# ---------------------------------------------------------------------------


class TestSSD:
    def _naive(self, xh, Bm, Cm, dt, A):
        """Literal per-step recurrence h_t = e^{dt A} h + dt B x; y = C h."""
        b, s, h, p = xh.shape
        n = Bm.shape[-1]
        hstate = np.zeros((b, h, p, n), np.float64)
        ys = np.zeros((b, s, h, p), np.float64)
        for t in range(s):
            decay = np.exp(dt[:, t] * A[None, :])  # (B,H)
            hstate = hstate * decay[:, :, None, None] + np.einsum(
                "bh,bn,bhp->bhpn", dt[:, t], Bm[:, t], xh[:, t]
            )
            ys[:, t] = np.einsum("bn,bhpn->bhp", Cm[:, t], hstate)
        return ys, hstate

    def test_chunked_matches_recurrence(self):
        rng = np.random.RandomState(0)
        b, s, h, p, n = 2, 64, 3, 4, 8
        cfg = ModelConfig(name="t", family="ssm", source="t", ssm_chunk=16,
                          ssm_state=n, ssm_head_dim=p)
        xh = rng.randn(b, s, h, p).astype(np.float32)
        Bm = rng.randn(b, s, n).astype(np.float32)
        Cm = rng.randn(b, s, n).astype(np.float32)
        dt = rng.uniform(0.01, 0.3, (b, s, h)).astype(np.float32)
        A = -rng.uniform(0.1, 2.0, (h,)).astype(np.float32)
        y, hT = ssm_mod.ssd_chunked(cfg, jnp.asarray(xh), jnp.asarray(Bm),
                                    jnp.asarray(Cm), jnp.asarray(dt), jnp.asarray(A))
        y_ref, h_ref = self._naive(xh, Bm, Cm, dt, A)
        np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(hT), h_ref, rtol=2e-4, atol=2e-4)

    def test_decode_matches_forward(self):
        """Recurrent decode over a sequence == chunked forward, token-wise."""
        cfg = get_config("mamba2-2.7b", reduced=True)
        spec = cfg.pattern[0]
        params = tf.init_params(jax.random.PRNGKey(0), cfg)
        # single-layer apply through the ssm block directly
        p_block = jax.tree.map(lambda x: x[0], params["pattern"])[0]["ssm"]
        b, s = 2, 24
        x = jax.random.normal(jax.random.PRNGKey(1), (b, s, cfg.d_model), jnp.float32)
        y_full = ssm_mod.ssm_forward(p_block, cfg, x)

        cache = ssm_mod.ssm_init_cache(cfg, b, jnp.float32)
        outs = []
        for t in range(s):
            y_t, cache = ssm_mod.ssm_decode(p_block, cfg, x[:, t : t + 1], cache)
            outs.append(y_t)
        y_dec = jnp.concatenate(outs, axis=1)
        np.testing.assert_allclose(
            np.asarray(y_dec), np.asarray(y_full), rtol=2e-3, atol=2e-3
        )


# ---------------------------------------------------------------------------
# MoE: dense baseline vs capacity dispatch
# ---------------------------------------------------------------------------


class TestMoE:
    def _cfg(self, e=4, k=2, cap=8.0):
        return ModelConfig(
            name="t", family="moe", source="t", n_layers=1, d_model=32,
            n_experts=e, top_k=k, expert_ff=16, capacity_factor=cap,
            pattern=(LayerSpec(kind="moe"),), n_rep=1,
        )

    def test_dense_equals_dispatch_at_high_capacity(self):
        """With capacity >= N*k/E guaranteed, no token drops -> identical."""
        cfg = self._cfg(cap=8.0)
        p = moe_mod.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 32), jnp.float32)
        y_dense, aux_d = moe_mod.moe_dense(p, cfg, x)
        y_disp, aux_s = moe_mod.moe_dispatch(p, cfg, x)
        np.testing.assert_allclose(np.asarray(y_dense), np.asarray(y_disp),
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(float(aux_d), float(aux_s), rtol=1e-5)

    def test_grouped_dispatch_equals_dense_at_high_capacity(self):
        cfg = self._cfg(cap=8.0)
        p = moe_mod.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (3, 8, 32), jnp.float32)
        y_dense, aux_d = moe_mod.moe_dense(p, cfg, x)
        y_grp, aux_g = moe_mod.moe_dispatch_grouped(p, cfg, x)
        np.testing.assert_allclose(np.asarray(y_dense), np.asarray(y_grp),
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(float(aux_d), float(aux_g), rtol=1e-5)

    def test_grouped_dispatch_capacity_is_per_group(self):
        """Group capacity binds per batch row, not globally: a row that
        routes everything to one expert drops, others are unaffected."""
        cfg = self._cfg(e=2, k=1, cap=1.0)
        p = moe_mod.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32), jnp.float32)
        y, _ = moe_mod.moe_dispatch_grouped(p, cfg, x)
        assert np.all(np.isfinite(np.asarray(y)))

    def test_dispatch_drops_overflow(self):
        """Tiny capacity: output is finite and generally != dense."""
        cfg = self._cfg(cap=0.25)
        p = moe_mod.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32), jnp.float32)
        y, _ = moe_mod.moe_dispatch(p, cfg, x)
        assert np.all(np.isfinite(np.asarray(y)))

    def test_router_weights_sum_to_one_over_topk(self):
        cfg = self._cfg()
        p = moe_mod.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (24, 32), jnp.float32)
        w, idx, topw, aux = moe_mod._router(p, cfg, x)
        np.testing.assert_allclose(np.asarray(jnp.sum(w, -1)), 1.0, rtol=1e-5)
        assert float(aux) >= 1.0 - 1e-5  # E * sum f_e P_e >= 1 (Cauchy-Schwarz)


# ---------------------------------------------------------------------------
# Attention: decode path == full forward (cache equivalence)
# ---------------------------------------------------------------------------


class TestAttentionCache:
    @pytest.mark.parametrize("arch", ["gemma3-1b", "gemma2-9b", "granite-3-2b", "zamba2-2.7b"])
    def test_decode_matches_prefill_logits(self, arch):
        """Greedy decode logits at position t == full-forward logits at t."""
        cfg = get_config(arch, reduced=True)
        params = tf.init_params(jax.random.PRNGKey(0), cfg)
        b, s = 1, 12
        batch = make_batch(cfg, b=b, s=s, seed=3)
        hidden, _ = tf.forward(params, cfg, batch)
        full_logits = tf.lm_logits(params, cfg, hidden)  # (B,S,V)

        caches = tf.init_caches(cfg, b, s)
        toks = batch["tokens"]
        for t in range(s):
            db = {"tokens": toks[:, t : t + 1]}
            if cfg.frontend == "vision_stub":
                db["patch_embeds"] = batch["patch_embeds"][:, :0]
            logits, caches = tf.decode_step(
                params, cfg, db, jnp.asarray(t, jnp.int32), caches
            )
            np.testing.assert_allclose(
                np.asarray(logits[:, 0], np.float32),
                np.asarray(full_logits[:, t], np.float32),
                rtol=5e-3, atol=5e-3,
            )


# ---------------------------------------------------------------------------
# CNN
# ---------------------------------------------------------------------------


class TestPrefillHandoff:
    @pytest.mark.parametrize("arch", ["gemma3-1b", "granite-3-2b", "mamba2-2.7b",
                                      "zamba2-2.7b", "olmoe-1b-7b"])
    def test_prefill_caches_continue_decode(self, arch):
        """prefill_with_caches(prompt) + decode_step(next) must equal
        running the full sequence through forward()."""
        cfg = get_config(arch, reduced=True)
        params = tf.init_params(jax.random.PRNGKey(0), cfg)
        b, s = 1, 16
        toks = jax.random.randint(jax.random.PRNGKey(1), (b, s + 1), 0, cfg.vocab_size)

        # oracle: full forward over s+1 tokens, logits at the last position
        full = {"tokens": toks}
        hidden, _ = tf.forward(params, cfg, full)
        want = tf.lm_logits(params, cfg, hidden[:, -1:, :])

        # prefill s tokens -> decode token s
        logits_p, caches = tf.prefill_with_caches(params, cfg, {"tokens": toks[:, :s]})
        got, _ = tf.decode_step(params, cfg, {"tokens": toks[:, s : s + 1]},
                                jnp.asarray(s, jnp.int32), caches)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            rtol=5e-3, atol=5e-3,
        )
        # the prefill's own last-token logits match forward at position s-1
        np.testing.assert_allclose(
            np.asarray(logits_p, np.float32),
            np.asarray(tf.lm_logits(params, cfg, hidden[:, s - 1 : s, :]), np.float32),
            rtol=5e-3, atol=5e-3,
        )


class TestKVQuant:
    def test_roundtrip_error_bound(self):
        from repro.models.attention import _quantize

        x = jax.random.normal(jax.random.PRNGKey(0), (4, 2, 8, 64)) * 5.0
        q, s = _quantize(x)
        assert q.dtype == jnp.int8
        deq = q.astype(jnp.float32) * np.asarray(s, np.float32)[..., None]
        rel = np.max(np.abs(deq - np.asarray(x))) / np.max(np.abs(np.asarray(x)))
        assert rel < 0.01  # 127-level symmetric quant

    def test_quantized_decode_close_to_exact(self):
        """int8-cache decode logits track the bf16-cache logits closely."""
        cfg = get_config("granite-3-2b", reduced=True)
        params = tf.init_params(jax.random.PRNGKey(0), cfg)
        toks = jax.random.randint(jax.random.PRNGKey(1), (1, 10), 0, cfg.vocab_size)
        outs = {}
        for quant in [False, True]:
            c = cfg.replace(kv_quant=quant)
            caches = tf.init_caches(c, 1, 10)
            for t in range(10):
                logits, caches = tf.decode_step(
                    params, c, {"tokens": toks[:, t : t + 1]},
                    jnp.asarray(t, jnp.int32), caches)
            outs[quant] = np.asarray(logits, np.float32)
        # same argmax, small logit drift
        assert np.argmax(outs[False]) == np.argmax(outs[True])
        drift = np.max(np.abs(outs[True] - outs[False]))
        assert drift < 0.15 * np.max(np.abs(outs[False])), drift


class TestCNN:
    def test_forward_and_learning(self):
        from repro.configs.resnet_cifar import SMALL_CNN

        cfg = SMALL_CNN
        params = cnn.init_params(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, 16, 3), jnp.float32)
        y = jax.random.randint(jax.random.PRNGKey(2), (8,), 0, cfg.n_classes)
        batch = {"images": x, "labels": y}
        loss0 = float(cnn.loss_fn(params, cfg, batch))
        g = jax.grad(cnn.loss_fn)(params, cfg, batch)
        params2 = jax.tree.map(lambda p, gi: p - 0.1 * gi, params, g)
        loss1 = float(cnn.loss_fn(params2, cfg, batch))
        assert np.isfinite(loss0) and loss1 < loss0
