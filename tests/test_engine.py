"""Federation-engine backend tests (DESIGN.md §3).

Parity: VmapBackend and ShardMapBackend must produce identical per-round
loss/acc histories on the same seed — exactly on a 1-device mesh (same
program, degenerate shard), and again on a 4-way forced-host-device mesh
(run in a subprocess because XLA device count is fixed at jax init; see
tests/conftest.py).
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.configs.resnet_cifar import SMALL_CNN
from repro.core.baselines import METHODS
from repro.data import FederatedData, dirichlet_partition, make_class_conditional_images
from repro.fl import Federation, FLRunConfig, make_engine, resolve_shards
from repro.fl.runtime import masked_accuracy, validate_method
from repro.models import cnn

CFG = SMALL_CNN
REPO = Path(__file__).resolve().parents[1]


@pytest.fixture(scope="module")
def setup():
    images, labels = make_class_conditional_images(800, CFG.n_classes,
                                                   CFG.cnn_image_size, seed=0)
    parts = dirichlet_partition(labels, 8, alpha=0.3, seed=0)
    data = FederatedData.from_partition(images, labels, parts, seed=0)
    params = cnn.init_params(jax.random.PRNGKey(0), CFG)
    loss = lambda p, b: cnn.loss_fn(p, CFG, b)
    acc = masked_accuracy(lambda p, t: cnn.apply(p, CFG, t["images"]))
    return data, params, loss, acc


def _history(backend, setup, method="pfedsop", rounds=3):
    data, params, loss, acc = setup
    run_cfg = FLRunConfig(n_clients=8, participation=0.5, rounds=rounds,
                          batch=8, local_iters=2, seed=1, backend=backend)
    fed = Federation(METHODS[method](), loss, acc, params, data, run_cfg)
    return fed.run()


@pytest.mark.parametrize("method", ["pfedsop", "fedavg"])
def test_backend_parity_single_device(setup, method):
    """vmap and shard_map histories are bit-identical on a 1-device mesh.

    Exact ``==`` is an intentional canary: on a 1-shard mesh the two
    backends must lower to the same program, so any drift (e.g. from a jax
    upgrade changing shard_map fusion) should be looked at, not hidden by a
    tolerance.  The multi-device variant below uses assert_allclose, where
    cross-shard reduction order may legitimately differ.
    """
    h_vmap = _history("vmap", setup, method)
    h_shard = _history("shard_map", setup, method)
    assert h_vmap["loss"] == h_shard["loss"]
    assert h_vmap["acc"] == h_shard["acc"]
    assert h_shard["engine"]["backend"] == "shard_map"
    assert h_vmap["engine"] == {"backend": "vmap", "shards": 1}


def test_resolve_shards_divisor_fallback():
    """Auto shard count = largest divisor of K' that fits the devices."""
    assert resolve_shards(kprime=4, n_devices=1) == 1
    assert resolve_shards(kprime=4, n_devices=4) == 4
    assert resolve_shards(kprime=6, n_devices=4) == 3
    assert resolve_shards(kprime=7, n_devices=4) == 1  # prime K'
    assert resolve_shards(kprime=8, n_devices=64) == 8  # capped at K'
    assert resolve_shards(kprime=8, n_devices=4, requested=2) == 2
    with pytest.raises(ValueError):
        resolve_shards(kprime=8, n_devices=4, requested=8)  # > devices
    with pytest.raises(ValueError):
        resolve_shards(kprime=8, n_devices=4, requested=3)  # non-divisor
    with pytest.raises(ValueError):
        resolve_shards(kprime=8, n_devices=4, requested=-2)  # negative


def test_make_engine_rejects_unknown_backend():
    with pytest.raises(ValueError, match="unknown FL backend"):
        make_engine("mpi", kprime=4)


def test_make_engine_rejects_shards_with_vmap():
    """A device-split request must not be silently ignored."""
    with pytest.raises(ValueError, match="shard_map"):
        make_engine("vmap", kprime=4, shards=2)


def test_validate_method_rejects_partial_interface():
    class Broken:
        name = "broken"

        def init_client(self, params):
            return {}

    with pytest.raises(TypeError, match="FLMethod interface"):
        validate_method(Broken())


_MULTIDEV_SCRIPT = textwrap.dedent(
    """
    import jax, numpy as np
    assert len(jax.devices()) == 4, jax.devices()
    from repro.configs.resnet_cifar import SMALL_CNN as CFG
    from repro.core.baselines import METHODS
    from repro.data import (FederatedData, dirichlet_partition,
                            make_class_conditional_images)
    from repro.fl import Federation, FLRunConfig
    from repro.fl.runtime import masked_accuracy
    from repro.models import cnn

    images, labels = make_class_conditional_images(600, CFG.n_classes,
                                                   CFG.cnn_image_size, seed=0)
    parts = dirichlet_partition(labels, 8, alpha=0.3, seed=0)
    data = FederatedData.from_partition(images, labels, parts, seed=0)
    params = cnn.init_params(jax.random.PRNGKey(0), CFG)
    loss = lambda p, b: cnn.loss_fn(p, CFG, b)
    acc = masked_accuracy(lambda p, t: cnn.apply(p, CFG, t["images"]))

    hists = {}
    for backend in ["vmap", "shard_map"]:
        cfg = FLRunConfig(n_clients=8, participation=0.5, rounds=2, batch=8,
                          local_iters=2, seed=1, backend=backend)
        fed = Federation(METHODS["pfedsop"](), loss, acc, params, data, cfg)
        hists[backend] = fed.run()
    assert hists["shard_map"]["engine"]["shards"] == 4, hists["shard_map"]["engine"]
    np.testing.assert_allclose(hists["vmap"]["loss"], hists["shard_map"]["loss"],
                               rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(hists["vmap"]["acc"], hists["shard_map"]["acc"],
                               rtol=1e-6, atol=1e-7)
    print("MULTIDEV_PARITY_OK")
    """
)


def test_backend_parity_multi_device():
    """shard_map over 4 forced host devices matches vmap on the same seed.

    Subprocess: the XLA device count must be set before jax initialises,
    and the rest of the suite needs the single real CPU device.
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=4"
    ).strip()
    env["PYTHONPATH"] = str(REPO / "src") + os.pathsep + env.get("PYTHONPATH", "")
    res = subprocess.run(
        [sys.executable, "-c", _MULTIDEV_SCRIPT],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert res.returncode == 0, f"stdout:\n{res.stdout}\nstderr:\n{res.stderr}"
    assert "MULTIDEV_PARITY_OK" in res.stdout


def test_shard_map_beats_or_matches_vmap_round_shape(setup):
    """Sanity: the sharded backend reports the same metrics *structure* and
    finite values (rounds/sec comparison itself lives in benchmarks/run.py)."""
    h = _history("shard_map", setup, "fedavg", rounds=2)
    assert len(h["loss"]) == 2 and len(h["round_time"]) == 2
    assert all(np.isfinite(v) for v in h["loss"])
    assert all(0.0 <= a <= 1.0 for a in h["acc"])
