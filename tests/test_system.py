"""End-to-end system behaviour tests (the public API as a user sees it)."""
import json
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

REPO = Path(__file__).resolve().parents[1]


class TestCheckpointing:
    def test_roundtrip(self, tmp_path):
        from repro.utils.checkpoint import load_checkpoint, save_checkpoint, latest_step

        tree = {
            "a": jnp.arange(6.0).reshape(2, 3),
            "n": {"b": jnp.ones((4,), jnp.int32), "c": (jnp.zeros(2), jnp.ones(3))},
        }
        save_checkpoint(tmp_path, 3, tree, extra={"note": "x"})
        save_checkpoint(tmp_path, 7, jax.tree.map(lambda x: x + 1, tree))
        assert latest_step(tmp_path) == 7
        template = jax.tree.map(jnp.zeros_like, tree)
        restored, extra = load_checkpoint(tmp_path, template, step=3)
        assert extra == {"note": "x"}
        for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(tree)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_structure_mismatch_raises(self, tmp_path):
        from repro.utils.checkpoint import load_checkpoint, save_checkpoint

        save_checkpoint(tmp_path, 0, {"a": jnp.zeros(2)})
        with pytest.raises(AssertionError):
            load_checkpoint(tmp_path, {"b": jnp.zeros(2)}, step=0)


class TestQuickstartExample:
    def test_quickstart_runs(self):
        """The quickstart example executes and reaches its asserts."""
        r = subprocess.run(
            [sys.executable, str(REPO / "examples" / "quickstart.py")],
            env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"},
            capture_output=True, text=True, timeout=600,
        )
        assert r.returncode == 0, r.stderr[-2000:]
        assert "OK" in r.stdout


class TestConfigRegistry:
    def test_all_archs_resolve(self):
        from repro.configs import ARCH_NAMES, get_config

        assert len(ARCH_NAMES) == 10
        for name in ARCH_NAMES:
            cfg = get_config(name)
            assert cfg.n_layers == len(cfg.layers)
            red = get_config(name, reduced=True)
            assert red.d_model <= 512
            assert not red.n_experts or red.n_experts <= 4

    def test_assigned_dims_match_brief(self):
        """Spot-check the assigned table (source-of-truth audit)."""
        from repro.configs import get_config

        g = get_config("gemma3-1b")
        assert (g.n_layers, g.d_model, g.n_heads, g.n_kv_heads, g.d_ff,
                g.vocab_size) == (26, 1152, 4, 1, 6912, 262144)
        m = get_config("mamba2-2.7b")
        assert (m.n_layers, m.d_model, m.ssm_state) == (64, 2560, 128)
        z = get_config("zamba2-2.7b")
        assert (z.n_layers, z.d_model, z.ssm_state, z.vocab_size) == (54, 2560, 64, 32000)
        o = get_config("olmoe-1b-7b")
        assert (o.n_experts, o.top_k, o.expert_ff) == (64, 8, 1024)
        gm = get_config("granite-moe-1b-a400m")
        assert (gm.n_experts, gm.top_k) == (32, 8)
        g2 = get_config("gemma2-9b")
        assert (g2.attn_softcap, g2.final_softcap) == (50.0, 30.0)
        iv = get_config("internvl2-2b")
        assert (iv.n_layers, iv.d_model, iv.vocab_size) == (24, 2048, 92553)
        mg = get_config("musicgen-large")
        assert (mg.n_layers, mg.d_model, mg.n_codebooks, mg.vocab_size) == (48, 2048, 4, 2048)

    def test_input_shapes(self):
        from repro.configs import INPUT_SHAPES

        assert INPUT_SHAPES["train_4k"].seq_len == 4096
        assert INPUT_SHAPES["train_4k"].global_batch == 256
        assert INPUT_SHAPES["prefill_32k"].global_batch == 32
        assert INPUT_SHAPES["decode_32k"].global_batch == 128
        assert INPUT_SHAPES["long_500k"].seq_len == 524288


class TestOptim:
    def test_sgd_momentum_adam_reduce_quadratic(self):
        from repro.optim import adam, apply_updates, momentum, sgd

        for opt in [sgd(0.1), momentum(0.05), adam(0.1)]:
            init, update = opt
            params = {"w": jnp.full((4,), 5.0)}
            state = init(params)
            for _ in range(60):
                g = jax.grad(lambda p: 0.5 * jnp.sum(p["w"] ** 2))(params)
                upd, state = update(g, state, params)
                params = apply_updates(params, upd)
            assert float(jnp.max(jnp.abs(params["w"]))) < 0.5


class TestDryrunArtifacts:
    """The dry-run sweep writes auditable artifacts; verify their schema
    (the sweep itself runs in its own 512-device process)."""

    def test_artifacts_schema(self):
        art = REPO / "experiments" / "dryrun"
        files = list(art.glob("*.json"))
        if not files:
            pytest.skip("dry-run sweep not yet executed")
        r = json.loads(files[0].read_text())
        for key in ["arch", "shape", "mesh", "memory_analysis", "cost_analysis",
                    "collectives", "roofline"]:
            assert key in r, key
        assert r["roofline"]["dominant"] in ("compute", "memory", "collective")
