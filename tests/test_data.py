"""Data-substrate tests: partitioner invariants (hypothesis property tests)
+ federated container + synthetic generators."""
import numpy as np
import pytest
from hyp_compat import given, hst, settings  # optional-hypothesis shim

from repro.data import (
    FederatedData,
    dirichlet_partition,
    make_class_conditional_images,
    pathological_partition,
    synthetic_lm_stream,
    lm_batch_iterator,
)


class TestDirichletPartition:
    @given(
        n=hst.integers(200, 2000),
        n_classes=hst.integers(2, 10),
        k=hst.integers(2, 20),
        alpha=hst.floats(0.05, 10.0),
        seed=hst.integers(0, 1000),
    )
    @settings(max_examples=25, deadline=None)
    def test_partition_is_exact_cover(self, n, n_classes, k, alpha, seed):
        """Every sample index appears in exactly one client."""
        rng = np.random.RandomState(seed)
        labels = rng.randint(0, n_classes, n)
        parts = dirichlet_partition(labels, k, alpha, seed=seed)
        allidx = np.concatenate(parts)
        assert len(allidx) == n
        assert len(np.unique(allidx)) == n

    def test_low_alpha_is_heterogeneous(self):
        """Dir(0.07) concentrates each class on few clients (paper setting)."""
        rng = np.random.RandomState(0)
        labels = rng.randint(0, 10, 20000)
        parts = dirichlet_partition(labels, 100, alpha=0.07, seed=0)
        # per-client label entropy should be far below uniform
        ents = []
        for idx in parts:
            if len(idx) < 10:
                continue
            p = np.bincount(labels[idx], minlength=10) / len(idx)
            p = p[p > 0]
            ents.append(-(p * np.log(p)).sum())
        assert np.mean(ents) < 0.5 * np.log(10)


class TestPathologicalPartition:
    @given(seed=hst.integers(0, 200))
    @settings(max_examples=20, deadline=None)
    def test_clients_see_few_classes(self, seed):
        """Shard partitioner: each client sees ~b classes (paper: b=2 CIFAR10)."""
        rng = np.random.RandomState(seed)
        n, k, z = 4000, 10, 200  # -> 20 shards, b=2 per client
        labels = np.sort(rng.randint(0, 10, n))
        rng.shuffle(labels)
        parts = pathological_partition(labels, k, shard_size=z, seed=seed)
        for idx in parts:
            assert len(idx) == (n // (k * z)) * z * ((n // z) // k) or len(idx) > 0
            n_cls = len(np.unique(labels[idx]))
            assert n_cls <= 4  # b=2 shards -> at most ~3 classes (shard spans)

    def test_disjoint_and_sized(self):
        labels = np.repeat(np.arange(10), 400)
        parts = pathological_partition(labels, 10, shard_size=200, seed=0)
        allidx = np.concatenate(parts)
        assert len(np.unique(allidx)) == len(allidx)
        for idx in parts:
            assert len(idx) == 400  # 2 shards x 200


class TestFederatedData:
    def _make(self, k=8, n=800):
        images, labels = make_class_conditional_images(n, 4, image_size=8, seed=0)
        parts = dirichlet_partition(labels, k, 0.5, seed=0)
        return FederatedData.from_partition(images, labels, parts, seed=0), labels

    def test_split_fractions(self):
        data, _ = self._make()
        total = data.train_counts.sum() + data.test_counts.sum()
        assert total <= 800
        assert (data.train_counts >= data.test_counts).mean() > 0.7

    def test_sample_round_batches_shapes_and_membership(self):
        data, labels = self._make()
        rng = np.random.RandomState(1)
        ids = np.array([0, 3, 5])
        b = data.sample_round_batches(rng, ids, T=4, batch=6)
        assert b["images"].shape == (3, 4, 6, 8, 8, 3)
        assert b["labels"].shape == (3, 4, 6)
        # sampled labels must come from the client's own train indices
        for i, cid in enumerate(ids):
            own = set(labels[data.train_idx[cid][: data.train_counts[cid]]])
            got = set(np.asarray(b["labels"][i]).ravel())
            assert got <= own

    def test_client_test_set_mask(self):
        data, _ = self._make()
        t = data.client_test_set(np.arange(8))
        assert t["mask"].shape == t["labels"].shape
        np.testing.assert_allclose(t["mask"].sum(1), data.test_counts)


class TestSynthetic:
    def test_images_learnable_structure(self):
        """Class templates are separable: nearest-template classification
        beats chance by a wide margin."""
        images, labels = make_class_conditional_images(600, 5, image_size=8, seed=0)
        assert images.shape == (600, 8, 8, 3)
        means = np.stack([images[labels == c].mean(0) for c in range(5)])
        d = ((images[:, None] - means[None]) ** 2).sum((2, 3, 4))
        acc = (d.argmin(1) == labels).mean()
        assert acc > 0.6, acc

    def test_lm_stream_markov_structure(self):
        s = synthetic_lm_stream(5000, 64, seed=0, branch=4)
        assert s.min() >= 0 and s.max() < 64
        # each token has at most `branch` successors
        succ = {}
        for a, b in zip(s[:-1], s[1:]):
            succ.setdefault(int(a), set()).add(int(b))
        assert max(len(v) for v in succ.values()) <= 4

    def test_lm_batch_iterator(self):
        s = synthetic_lm_stream(2000, 32, seed=0)
        it = lm_batch_iterator(s, batch=4, seq_len=16, seed=0)
        b = next(it)
        assert b["tokens"].shape == (4, 16)
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])
