"""Unit tests for repro.obs (DESIGN.md §13): tracer span semantics,
Chrome-trace export schema, metrics histograms, the structured logger,
fingerprint-stamped resume-append, and the zero-bytes-disabled contract.
The cross-backend bitwise invariance contract lives in
tests/test_obs_invariance.py (forced 8-device subprocess)."""
import json
import logging

import jax
import numpy as np
import pytest

from repro.obs import (
    NOOP,
    Histogram,
    MetricsRegistry,
    Obs,
    ObsConfig,
    Tracer,
    as_obs_config,
    export_chrome,
    make_obs,
    read_events,
    read_metrics,
)
from repro.obs.log import ObsLog


class TestObsConfig:
    def test_bad_level_raises(self):
        with pytest.raises(ValueError, match="obs level"):
            ObsConfig(trace_dir="x", level="verbose")

    def test_as_obs_config_accepts_none_config_dict(self):
        assert as_obs_config(None) is None
        cfg = ObsConfig(trace_dir="x")
        assert as_obs_config(cfg) is cfg
        assert as_obs_config({"trace_dir": "y"}).trace_dir == "y"
        with pytest.raises(TypeError, match="obs must be"):
            as_obs_config(42)

    def test_enabled_requires_level_and_sink(self):
        assert not make_obs(None).enabled
        assert make_obs(None) is NOOP
        assert not Obs(ObsConfig(level="off", trace_dir="x")).enabled
        assert not Obs(ObsConfig(level="phase")).enabled  # no sink
        assert Obs(ObsConfig(level="phase", trace_dir="x")).enabled

    def test_disabled_facade_writes_nothing(self, tmp_path):
        target = tmp_path / "never"
        obs = Obs(ObsConfig(level="off", trace_dir=str(target)))
        obs.open(fingerprint={"a": 1})
        with obs.span("round"):
            obs.event("x")
            obs.flush_metrics(step=0)
        obs.close()
        assert not target.exists()
        assert list(tmp_path.iterdir()) == []


class TestTracer:
    def test_span_nesting_depth(self, tmp_path):
        tr = Tracer(tmp_path / "t", fingerprint={"s": 1})
        with tr.span("outer"):
            with tr.span("inner", track="srv"):
                with tr.span("leaf"):
                    pass
        tr.event("done")
        tr.close()
        evs = read_events(tmp_path / "t")
        spans = {e["name"]: e for e in evs if e["k"] == "span"}
        # spans are written at exit (innermost first) with entry-time depth
        assert [e["name"] for e in evs if e["k"] == "span"] == [
            "leaf", "inner", "outer"]
        assert spans["outer"]["depth"] == 0
        assert spans["inner"]["depth"] == 1
        assert spans["leaf"]["depth"] == 2
        assert all("dur" in s and "ts" in s for s in spans.values())

    def test_resume_appends_with_marker(self, tmp_path):
        fp = {"seed": 3, "driver": "sync"}
        tr = Tracer(tmp_path / "t", fingerprint=fp)
        tr.event("first")
        tr.close()
        tr2 = Tracer(tmp_path / "t", fingerprint=fp)
        tr2.event("second")
        tr2.close()
        names = [e["name"] for e in read_events(tmp_path / "t")
                 if e["k"] == "ev"]
        assert names == ["first", "resume", "second"]
        marker = [e for e in read_events(tmp_path / "t")
                  if e["name"] == "resume"][0]
        assert marker["cat"] == "marker"

    def test_fingerprint_mismatch_raises(self, tmp_path):
        Tracer(tmp_path / "t", fingerprint={"seed": 3}).close()
        with pytest.raises(ValueError, match="incomparable timelines"):
            Tracer(tmp_path / "t", fingerprint={"seed": 4})

    def test_chrome_export_schema(self, tmp_path):
        tr = Tracer(tmp_path / "t", fingerprint=None)
        with tr.span("round", sim=2.5):
            pass
        tr.event("dispatch", track="async", sim=1.0, cohort=3)
        tr.client_span(7, "inflight", 1.0, 4.0, pod=1)
        tr.sink({"k": "log", "event": "round", "msg": "hi"})
        tr.close()
        path = export_chrome(tmp_path / "t")
        doc = json.loads(path.read_text())
        assert path.name == "trace.json"
        assert doc["displayTimeUnit"] == "ms"
        evs = doc["traceEvents"]
        assert isinstance(evs, list)
        for e in evs:
            assert e["ph"] in ("M", "X", "i")
            assert isinstance(e["pid"], int) and "name" in e
            if e["ph"] == "X":
                assert isinstance(e["ts"], int) and isinstance(e["dur"], int)
        # client span: sim pid, tid = client+1, sim seconds -> trace µs
        cspan = [e for e in evs if e["ph"] == "X" and e["pid"] == 2][0]
        assert cspan["tid"] == 8
        assert cspan["ts"] == 1_000_000 and cspan["dur"] == 3_000_000
        # sim-annotated server records mirror as instants on the sim track
        mirrors = [e for e in evs if e["ph"] == "i" and e["pid"] == 2]
        assert {m["name"] for m in mirrors} == {"round", "dispatch"}
        # log records never become timeline entries
        assert not any(e.get("cat") == "log" for e in evs)

    def test_zero_duration_cspan_renders_visible(self, tmp_path):
        tr = Tracer(tmp_path / "t", fingerprint=None)
        tr.client_span(0, "buffered", 2.0, 2.0)
        tr.close()
        doc = json.loads(export_chrome(tmp_path / "t").read_text())
        cspan = [e for e in doc["traceEvents"] if e["ph"] == "X"][0]
        assert cspan["dur"] == 1


class TestHistogram:
    def test_right_open_buckets(self):
        h = Histogram(edges=[1.0, 2.0, 4.0])
        h.observe([0.5, 1.0, 1.5, 2.0, 3.9, 4.0, 100.0])
        assert h.counts == [1, 2, 2, 2]  # <1, [1,2), [2,4), >=4
        assert h.count == 7
        assert h.min == 0.5 and h.max == 100.0
        assert h.sum == pytest.approx(112.9)

    def test_accepts_scalars_and_arrays(self):
        h = Histogram(edges=[0.5])
        h.observe(0.1)
        h.observe(np.asarray([[0.6, 0.7], [0.1, 0.9]]))
        assert h.counts == [2, 3]
        h.observe(np.asarray([]))  # empty observation is a no-op
        assert h.count == 5

    def test_non_ascending_edges_raise(self):
        with pytest.raises(ValueError, match="ascending"):
            Histogram(edges=[2.0, 1.0])
        with pytest.raises(ValueError, match="ascending"):
            Histogram(edges=[])

    def test_snapshot_roundtrips_through_json(self):
        h = Histogram(edges=[1.0])
        h.observe([0.5, 2.0])
        snap = json.loads(json.dumps(h.snapshot()))
        assert snap["counts"] == [1, 1] and snap["count"] == 2


class TestMetricsRegistry:
    def test_counters_gauges_flush(self, tmp_path):
        reg = MetricsRegistry(tmp_path / "m.jsonl")
        reg.counter("rounds").inc()
        reg.counter("rounds").inc(2)
        reg.gauge("loss").set(0.5)
        reg.histogram("tau", edges=[1.0]).observe([0.0, 3.0])
        reg.flush(step=0, sim_time=1.5)
        reg.gauge("loss").set(0.25)
        reg.flush(step=1)
        reg.close()
        snaps = read_metrics(tmp_path / "m.jsonl")
        assert len(snaps) == 2
        assert snaps[0]["step"] == 0 and snaps[0]["sim_time"] == 1.5
        assert snaps[0]["counters"]["rounds"] == 3
        assert snaps[1]["gauges"]["loss"] == 0.25
        assert snaps[1]["histograms"]["tau"]["counts"] == [1, 1]

    def test_set_gauges_skips_non_numeric(self, tmp_path):
        reg = MetricsRegistry(None)
        reg.set_gauges("store", {"h2d_bytes": 10, "kind": "host",
                                 "promoted": True, "rate": 0.5})
        snap = reg.snapshot()
        assert snap["gauges"] == {"store.h2d_bytes": 10.0, "store.rate": 0.5}

    def test_pathless_registry_never_writes(self):
        reg = MetricsRegistry(None)
        reg.counter("x").inc()
        reg.flush(step=0)  # no sink: a no-op, not an error
        reg.close()


class TestObsLog:
    def test_quiet_suppresses_stdout_not_sink(self, capsys):
        recs = []
        log = ObsLog(quiet=True, sink=recs.append)
        log.info("hello", event="greet", n=1)
        assert capsys.readouterr().out == ""
        assert recs[0]["k"] == "log" and recs[0]["event"] == "greet"
        assert recs[0]["msg"] == "hello" and recs[0]["fields"] == {"n": 1}

    def test_loud_prints(self, capsys):
        ObsLog(quiet=False).info("to stdout")
        assert capsys.readouterr().out == "to stdout\n"

    def test_stdlib_logger_routing(self, caplog, capsys):
        lg = logging.getLogger("repro.test.obslog")
        with caplog.at_level(logging.INFO, logger="repro.test.obslog"):
            ObsLog(quiet=False).info("via stdlib", logger=lg)
        assert [r.getMessage() for r in caplog.records] == ["via stdlib"]
        # logger routing replaces the print (no double emission)
        assert capsys.readouterr().out == ""

    def test_debug_is_sink_only(self, capsys):
        recs = []
        ObsLog(quiet=False, sink=recs.append).debug("quiet detail")
        assert capsys.readouterr().out == ""
        assert recs[0]["msg"] == "quiet detail"

    def test_non_jsonable_fields_coerced(self, tmp_path):
        recs = []
        ObsLog(quiet=True, sink=recs.append).info(
            "x", arr=np.float32(1.5), path=tmp_path)
        json.dumps(recs[0])  # must be serializable as written


class TestObsFacade:
    def test_timed_returns_value_and_records(self, tmp_path):
        obs = Obs(ObsConfig(trace_dir=str(tmp_path / "t"), level="phase"))
        obs.open(fingerprint={"x": 1})
        out = obs.timed("work", lambda a, b: a + b, 2, 3, round=0)
        obs.close()
        assert out == 5
        spans = [e for e in read_events(tmp_path / "t") if e["k"] == "span"]
        assert spans[0]["name"] == "work"
        assert spans[0]["args"]["round"] == 0

    def test_round_level_skips_phase_spans(self, tmp_path):
        obs = Obs(ObsConfig(trace_dir=str(tmp_path / "t"), level="round"))
        obs.open()
        obs.timed("work", lambda: 1)
        obs.event("marker")
        obs.close()
        evs = read_events(tmp_path / "t")
        assert [e["name"] for e in evs] == ["marker"]

    def test_default_metrics_path_lands_in_trace_dir(self, tmp_path):
        obs = Obs(ObsConfig(trace_dir=str(tmp_path / "t"), level="phase"))
        obs.open()
        obs.metrics.counter("n").inc()
        obs.flush_metrics(step=0)
        obs.close()
        assert obs.final_metrics["counters"]["n"] == 1
        assert read_metrics(tmp_path / "t" / "metrics.jsonl")
        assert (tmp_path / "t" / "trace.json").exists()

    def test_close_is_idempotent(self, tmp_path):
        obs = Obs(ObsConfig(trace_dir=str(tmp_path / "t"), level="phase"))
        obs.open()
        obs.close()
        obs.close()


def test_theta_from_beta_matches_reference_aux():
    """The metrics-side inversion must reproduce the angle the reference
    update path computed (the fused kernel carries only beta)."""
    from repro.core.pfedsop import gompertz_weight, theta_from_beta

    k = jax.random.PRNGKey(0)
    for lam in (0.5, 1.0, 5.0):
        di = jax.random.normal(k, (64,))
        dg = jax.random.normal(jax.random.fold_in(k, 1), (64,))
        _, aux = gompertz_weight(di, dg, lam=lam)
        theta = theta_from_beta(float(aux["beta"]), lam)
        np.testing.assert_allclose(theta, float(aux["theta"]),
                                   rtol=1e-5, atol=1e-6)
    # clipping keeps degenerate betas finite and in [0, pi]
    for b in (0.0, 1.0, -1.0, 2.0):
        assert 0.0 <= theta_from_beta(b, 1.0) <= np.pi


class TestFederationObs:
    """Driver-level integration on a tiny sync federation."""

    def _fed(self, tmp_path, obs=None, seed=0):
        from repro.configs.resnet_cifar import SMALL_CNN as CFG
        from repro.core.baselines import METHODS
        from repro.data import (FederatedData, dirichlet_partition,
                                make_class_conditional_images)
        from repro.fl import Federation, FLRunConfig
        from repro.fl.runtime import masked_accuracy
        from repro.models import cnn

        images, labels = make_class_conditional_images(
            200, CFG.n_classes, CFG.cnn_image_size, seed=0)
        parts = dirichlet_partition(labels, 4, alpha=0.3, seed=0)
        data = FederatedData.from_partition(images, labels, parts, seed=0)
        params = cnn.init_params(jax.random.PRNGKey(0), CFG)
        loss = lambda p, b: cnn.loss_fn(p, CFG, b)
        acc = masked_accuracy(lambda p, t: cnn.apply(p, CFG, t["images"]))
        cfg = FLRunConfig(n_clients=4, participation=0.5, rounds=2, batch=8,
                          local_iters=1, seed=seed, obs=obs)
        return Federation(METHODS["pfedsop"](), loss, acc, params, data, cfg)

    def test_traced_run_emits_phases_and_metrics(self, tmp_path):
        tdir = tmp_path / "t"
        obs = ObsConfig(trace_dir=str(tdir), level="phase", quiet=True)
        fed = self._fed(tmp_path, obs=obs)
        hist = fed.run(verbose=True)
        assert len(hist["loss"]) == 2
        evs = read_events(tdir)
        spans = {e["name"] for e in evs if e["k"] == "span"}
        assert {"round", "gather", "client", "eval", "aggregate",
                "scatter"} <= spans
        rounds = [e for e in evs if e["k"] == "span" and e["name"] == "round"]
        assert len(rounds) == 2
        snaps = read_metrics(tdir / "metrics.jsonl")
        assert snaps[-1]["counters"]["rounds"] == 2
        assert {"client.loss", "pfedsop.beta",
                "pfedsop.theta"} <= set(snaps[-1]["histograms"])
        assert (tdir / "trace.json").exists()
        # quiet mode: round prints were recorded, not printed
        logs = [e for e in evs if e.get("k") == "log" and e["event"] == "round"]
        assert len(logs) == 2

    def test_same_config_reopen_appends(self, tmp_path):
        obs = ObsConfig(trace_dir=str(tmp_path / "t"), level="round",
                        quiet=True)
        self._fed(tmp_path, obs=obs).run()
        self._fed(tmp_path, obs=obs).run()
        evs = read_events(tmp_path / "t")
        assert sum(1 for e in evs if e.get("name") == "resume") == 1

    def test_config_change_rejected(self, tmp_path):
        obs = ObsConfig(trace_dir=str(tmp_path / "t"), level="round",
                        quiet=True)
        self._fed(tmp_path, obs=obs, seed=0).run()
        with pytest.raises(ValueError, match="incomparable timelines"):
            self._fed(tmp_path, obs=obs, seed=1)
