"""Pallas kernel validation (interpret=True on CPU): shape/dtype sweeps
against the pure-jnp oracles + hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hyp_compat import given, hst, settings  # optional-hypothesis shim

from repro.kernels.flash_gqa.kernel import flash_gqa_grid, flash_gqa_pallas
from repro.kernels.flash_gqa.ops import flash_gqa
from repro.kernels.flash_gqa.ref import flash_gqa_ref
from repro.kernels.pfedsop_update.ops import (
    pfedsop_update,
    pfedsop_update_batched,
    pfedsop_update_tree,
)
from repro.kernels.pfedsop_update.ref import (
    pfedsop_update_batched_ref,
    pfedsop_update_ref,
)
from repro.kernels.rmsnorm.ops import rmsnorm
from repro.kernels.rmsnorm.ref import rmsnorm_ref
from repro.core import pfedsop as pf


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-5, atol=2e-5)


class TestRMSNorm:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("shape", [(8, 128), (3, 17, 256), (1, 1, 512), (64, 384)])
    def test_sweep(self, shape, dtype):
        x = jax.random.normal(jax.random.PRNGKey(0), shape, dtype)
        s = jax.random.normal(jax.random.PRNGKey(1), (shape[-1],), jnp.float32) * 0.2
        out = rmsnorm(x, s, interpret=True)
        ref = rmsnorm_ref(x, s)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32), **_tol(dtype)
        )

    @given(rows=hst.integers(1, 64), d_mult=hst.integers(1, 4))
    @settings(max_examples=10, deadline=None)
    def test_property_rows(self, rows, d_mult):
        d = 128 * d_mult
        x = jax.random.normal(jax.random.PRNGKey(rows), (rows, d), jnp.float32)
        s = jnp.zeros((d,), jnp.float32)
        out = rmsnorm(x, s, interpret=True)
        # unit scale -> rows have (approx) unit RMS
        rms = np.sqrt(np.mean(np.asarray(out) ** 2, -1))
        np.testing.assert_allclose(rms, 1.0, rtol=1e-3)


class TestPFedSOPUpdate:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("n", [7, 128, 1023, 4096, 50_000])
    def test_sweep_vs_ref(self, n, dtype):
        ks = jax.random.split(jax.random.PRNGKey(n), 3)
        x = jax.random.normal(ks[0], (n,), dtype)
        di = jax.random.normal(ks[1], (n,), dtype)
        dg = jax.random.normal(ks[2], (n,), dtype)
        out, beta = pfedsop_update(x, di, dg, eta1=0.03, rho=0.9, lam=1.1, interpret=True)
        ref, beta_r = pfedsop_update_ref(x, di, dg, 0.03, 0.9, 1.1)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32), **_tol(dtype)
        )
        np.testing.assert_allclose(float(beta), float(beta_r), rtol=1e-4)

    def test_matches_core_pfedsop_personalize(self):
        """Kernel path == the framework's pure-JAX personalize()."""
        key = jax.random.PRNGKey(0)
        tree = {
            "w": jax.random.normal(key, (33, 17)),
            "b": jax.random.normal(jax.random.fold_in(key, 1), (9,)),
        }
        di = jax.tree.map(lambda x: x * 0.1, tree)
        dg = jax.tree.map(lambda x: x * -0.05, tree)
        cfg = pf.PFedSOPConfig(eta1=0.02, rho=1.3, lam=0.8)
        expect, aux = pf.personalize(tree, di, dg, cfg)
        got, beta = pfedsop_update_tree(tree, di, dg, eta1=0.02, rho=1.3, lam=0.8,
                                        interpret=True)
        np.testing.assert_allclose(float(beta), float(aux["beta"]), rtol=1e-5)
        for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(expect)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)

    @given(
        n=hst.integers(4, 2000),
        eta=hst.floats(1e-4, 1.0),
        rho=hst.floats(0.05, 5.0),
        lam=hst.floats(0.2, 5.0),
        seed=hst.integers(0, 50),
    )
    @settings(max_examples=15, deadline=None)
    def test_property_random(self, n, eta, rho, lam, seed):
        ks = jax.random.split(jax.random.PRNGKey(seed), 3)
        x = jax.random.normal(ks[0], (n,))
        di = jax.random.normal(ks[1], (n,))
        dg = jax.random.normal(ks[2], (n,))
        out, beta = pfedsop_update(x, di, dg, eta1=eta, rho=rho, lam=lam, interpret=True)
        ref, _ = pfedsop_update_ref(x, di, dg, eta, rho, lam)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-4)
        assert 0.0 <= float(beta) <= 1.0


class TestPFedSOPUpdateBatched:
    """The (clients, N) grid variant the federation engines dispatch to."""

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("c,n", [(1, 7), (3, 128), (4, 1023), (5, 4096)])
    def test_sweep_vs_ref(self, c, n, dtype):
        ks = jax.random.split(jax.random.PRNGKey(c * n), 3)
        x = jax.random.normal(ks[0], (c, n), dtype)
        di = jax.random.normal(ks[1], (c, n), dtype)
        dg = jax.random.normal(ks[2], (c, n), dtype)
        out, beta = pfedsop_update_batched(x, di, dg, eta1=0.03, rho=0.9,
                                           lam=1.1, interpret=True)
        ref, beta_r = pfedsop_update_batched_ref(x, di, dg, 0.03, 0.9, 1.1)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32), **_tol(dtype)
        )
        np.testing.assert_allclose(np.asarray(beta), np.asarray(beta_r), rtol=1e-4)

    def test_shared_broadcast_delta(self):
        """A (N,) global delta (replicated server broadcast) must equal the
        explicitly tiled (C, N) form — the kernel reads one shared buffer."""
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        c, n = 4, 1000
        x = jax.random.normal(ks[0], (c, n))
        di = jax.random.normal(ks[1], (c, n))
        dg = jax.random.normal(ks[2], (n,))
        out_shared, beta_s = pfedsop_update_batched(x, di, dg, interpret=True)
        out_tiled, beta_t = pfedsop_update_batched(
            x, di, jnp.broadcast_to(dg, (c, n)), interpret=True)
        np.testing.assert_allclose(np.asarray(out_shared), np.asarray(out_tiled),
                                   rtol=1e-6, atol=1e-7)
        np.testing.assert_allclose(np.asarray(beta_s), np.asarray(beta_t), rtol=1e-6)

    def test_rows_equal_single_client_kernel(self):
        """Each batched row reproduces the single-client kernel: the grid
        layout must not change the per-client tile sums (tolerance covers
        XLA fusion/FMA differences between the two programs, not math)."""
        ks = jax.random.split(jax.random.PRNGKey(7), 3)
        c, n = 3, 2000
        x = jax.random.normal(ks[0], (c, n))
        di = jax.random.normal(ks[1], (c, n))
        dg = jax.random.normal(ks[2], (n,))
        out_b, beta_b = pfedsop_update_batched(x, di, dg, eta1=0.05, rho=1.2,
                                               lam=0.7, interpret=True)
        for i in range(c):
            out_1, beta_1 = pfedsop_update(x[i], di[i], dg, eta1=0.05, rho=1.2,
                                           lam=0.7, interpret=True)
            np.testing.assert_allclose(np.asarray(out_b[i]), np.asarray(out_1),
                                       rtol=1e-6, atol=1e-7)
            np.testing.assert_allclose(float(beta_b[i]), float(beta_1), rtol=1e-6)

    def test_zero_norm_deltas(self):
        """Zero local/global updates hit the cosine guard: neutral beta
        (theta = pi/2), finite output, x unchanged when both deltas vanish."""
        c, n = 2, 300
        x = jax.random.normal(jax.random.PRNGKey(0), (c, n))
        zeros = jnp.zeros((c, n))
        out, beta = pfedsop_update_batched(x, zeros, zeros, interpret=True)
        ref, beta_r = pfedsop_update_batched_ref(x, zeros, zeros, 0.01, 1.0, 1.0)
        assert np.all(np.isfinite(np.asarray(out)))
        np.testing.assert_allclose(np.asarray(beta), np.asarray(beta_r), rtol=1e-6)
        np.testing.assert_allclose(np.asarray(out), np.asarray(x), rtol=1e-6)


class TestFlashGQA:
    CASES = [
        # (b, h, kv, s, d, window, softcap, dtype)
        (1, 2, 1, 64, 32, None, None, jnp.float32),
        (2, 4, 2, 128, 64, None, 50.0, jnp.float32),
        (1, 8, 2, 256, 64, 48, None, jnp.float32),
        (1, 4, 4, 128, 128, 32, 30.0, jnp.float32),
        (2, 2, 1, 128, 64, None, None, jnp.bfloat16),
        (1, 16, 2, 64, 256, None, None, jnp.float32),  # gemma3-like ratios
    ]

    @pytest.mark.parametrize("case", CASES)
    def test_sweep_vs_ref(self, case):
        b, h, kv, s, d, win, cap, dtype = case
        ks = jax.random.split(jax.random.PRNGKey(s + h), 3)
        q = jax.random.normal(ks[0], (b, h, s, d), dtype)
        k = jax.random.normal(ks[1], (b, kv, s, d), dtype)
        v = jax.random.normal(ks[2], (b, kv, s, d), dtype)
        out = flash_gqa_pallas(q, k, v, window=win, softcap=cap, bq=32, bk=32,
                               interpret=True)
        ref = flash_gqa_ref(q, k, v, window=win, softcap=cap)
        tol = dict(rtol=3e-2, atol=3e-2) if dtype == jnp.bfloat16 else dict(rtol=3e-5, atol=3e-5)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32), **tol
        )

    def test_block_size_invariance(self):
        """Output must not depend on the BQ/BK tiling choice."""
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (1, 4, 128, 64))
        k = jax.random.normal(ks[1], (1, 2, 128, 64))
        v = jax.random.normal(ks[2], (1, 2, 128, 64))
        outs = [
            flash_gqa_pallas(q, k, v, window=40, bq=bq, bk=bk, interpret=True)
            for bq, bk in [(16, 16), (32, 64), (128, 128), (64, 16)]
        ]
        for o in outs[1:]:
            np.testing.assert_allclose(np.asarray(o), np.asarray(outs[0]),
                                       rtol=2e-5, atol=2e-5)

    def test_layout_wrapper(self):
        """ops.flash_gqa (B,S,H,D layout) == ref on transposed layout."""
        ks = jax.random.split(jax.random.PRNGKey(2), 3)
        q = jax.random.normal(ks[0], (2, 64, 4, 32))
        k = jax.random.normal(ks[1], (2, 64, 2, 32))
        v = jax.random.normal(ks[2], (2, 64, 2, 32))
        out = flash_gqa(q, k, v, bq=32, bk=32, interpret=True)
        ref = jnp.swapaxes(
            flash_gqa_ref(jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
                          jnp.swapaxes(v, 1, 2)), 1, 2)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=3e-5, atol=3e-5)

    def test_matches_model_attention_kernel_dispatch(self):
        """attention_fwd with kernel_impl="kernel_interpret" routes here:
        the dispatched model layer == its own reference impl."""
        from repro.configs import get_config
        from repro.models import attention as am

        cfg = get_config("gemma2-9b", reduced=True)
        b, s = 1, 64
        x = jax.random.normal(jax.random.PRNGKey(0), (b, s, cfg.d_model), jnp.float32)
        p = am.attn_init(jax.random.PRNGKey(1), cfg, jnp.float32)
        pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        ref = am.attention_fwd(p, cfg.replace(kernel_impl="reference"),
                               x, pos, window=32, rope_base=10_000.0, q_block=32)
        out = am.attention_fwd(p, cfg.replace(kernel_impl="kernel_interpret"),
                               x, pos, window=32, rope_base=10_000.0, q_block=32)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)

    def test_matches_model_attention_math(self):
        """Kernel == the model layer's blockwise attention (same math)."""
        from repro.configs import get_config
        from repro.models import attention as am

        cfg = get_config("gemma2-9b", reduced=True)
        b, s = 1, 64
        x = jax.random.normal(jax.random.PRNGKey(0), (b, s, cfg.d_model), jnp.float32)
        p = am.attn_init(jax.random.PRNGKey(1), cfg, jnp.float32)
        pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        q, k, v = am._project_qkv(p, cfg, x, pos, 10_000.0)
        ref = am.attention_fwd(p, cfg, x, pos, window=None, rope_base=10_000.0)
        out = flash_gqa(q, k, v, softcap=cfg.attn_softcap, bq=32, bk=32, interpret=True)
        out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-4)


class TestFlashGQAPruned:
    """Window-aware block-pruned KV grid: for sliding-window layers the
    kernel visits nkp = ceil((W+BQ)/BK)+1 k-blocks per q row instead of
    S/BK.  Parity is pruned vs unpruned vs reference, on window sizes
    smaller than, equal to, and not a multiple of the k-block size."""

    BK = 32
    # window: smaller than BK / equal to BK / not a multiple of BK
    WINDOWS = [16, 32, 40]

    def _qkv(self, s=256, d=64):
        ks = jax.random.split(jax.random.PRNGKey(s), 3)
        q = jax.random.normal(ks[0], (1, 4, s, d))
        k = jax.random.normal(ks[1], (1, 2, s, d))
        v = jax.random.normal(ks[2], (1, 2, s, d))
        return q, k, v

    @pytest.mark.parametrize("window", WINDOWS)
    def test_pruned_vs_unpruned_vs_ref(self, window):
        q, k, v = self._qkv()
        ref = flash_gqa_ref(q, k, v, window=window)
        pruned = flash_gqa_pallas(q, k, v, window=window, bq=self.BK,
                                  bk=self.BK, interpret=True, prune_window=True)
        unpruned = flash_gqa_pallas(q, k, v, window=window, bq=self.BK,
                                    bk=self.BK, interpret=True,
                                    prune_window=False)
        np.testing.assert_allclose(np.asarray(pruned), np.asarray(ref),
                                   rtol=3e-5, atol=3e-5)
        np.testing.assert_allclose(np.asarray(pruned), np.asarray(unpruned),
                                   rtol=1e-6, atol=1e-7)

    @pytest.mark.parametrize("window", WINDOWS)
    def test_grid_visits_fewer_k_blocks(self, window):
        s = 256
        nq_p, nk_p = flash_gqa_grid(s, self.BK, self.BK, window=window)
        nq_u, nk_u = flash_gqa_grid(s, self.BK, self.BK, window=window,
                                    prune_window=False)
        assert nq_p == nq_u
        assert nk_p < nk_u == s // self.BK
        # the flagged formula: nkp = ceil((W + BQ)/BK) + 1, capped at nk
        assert nk_p == min(s // self.BK, -(-(window + self.BK) // self.BK) + 1)

    def test_window_covering_sequence_disables_pruning(self):
        """W >= S: every k block is live, so the pruned grid must equal the
        unpruned one (no degenerate shrink)."""
        s = 128
        assert flash_gqa_grid(s, 32, 32, window=s) == \
            flash_gqa_grid(s, 32, 32, window=s, prune_window=False)

    def test_softcap_and_gqa_through_pruned_grid(self):
        q, k, v = self._qkv(s=128)
        ref = flash_gqa_ref(q, k, v, window=24, softcap=30.0)
        out = flash_gqa_pallas(q, k, v, window=24, softcap=30.0, bq=16,
                               bk=32, interpret=True, prune_window=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=3e-5, atol=3e-5)

    def test_ops_wrapper_grad_through_pruned_kernel(self):
        """The (B,S,H,D) wrapper is differentiable (reference-VJP backward):
        grads through the pruned kernel match grads through the oracle."""
        ks = jax.random.split(jax.random.PRNGKey(3), 3)
        s, d = 64, 32
        q = jax.random.normal(ks[0], (1, s, 4, d))
        k = jax.random.normal(ks[1], (1, s, 2, d))
        v = jax.random.normal(ks[2], (1, s, 2, d))

        def loss_kernel(q, k, v):
            return jnp.sum(flash_gqa(q, k, v, window=16, bq=16, bk=16,
                                     interpret=True) ** 2)

        def loss_ref(q, k, v):
            out = flash_gqa_ref(jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
                                jnp.swapaxes(v, 1, 2), window=16)
            return jnp.sum(out ** 2)

        g_k = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
        g_r = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
        for a, b in zip(g_k, g_r):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-4, atol=1e-4)


class TestFlashGQABackwardKernel:
    """Fused flash backward (DESIGN.md §9, kernel ``flash_gqa_bwd``): the
    two-pass Pallas backward (dq over the forward's pruned grid, dk/dv
    over the q-blocks visible to each k-block) must reproduce both the
    scan-of-VJPs reference backward and the oracle's autodiff grads —
    at full attention, under a sliding window (pruned grids on both
    passes), with softcap, and at S not a multiple of the block sizes."""

    # (b, h, kv, s, d, window, softcap, bq, bk)
    CASES = [
        (1, 4, 2, 128, 32, None, None, 32, 32),
        (1, 4, 2, 128, 32, 48, None, 32, 32),
        (1, 4, 2, 128, 32, 48, 30.0, 32, 32),
        (2, 4, 4, 128, 32, None, 30.0, 32, 32),
        (1, 4, 2, 80, 32, 24, None, 32, 32),   # S % block != 0 (halved)
        (1, 8, 2, 256, 64, 16, None, 64, 32),  # bq != bk, heavy pruning
    ]

    @staticmethod
    def _inputs(case):
        b, h, kv, s, d = case[:5]
        ks = jax.random.split(jax.random.PRNGKey(sum(case[:5])), 3)
        q = jax.random.normal(ks[0], (b, s, h, d), jnp.float32)
        k = jax.random.normal(ks[1], (b, s, kv, d), jnp.float32)
        v = jax.random.normal(ks[2], (b, s, kv, d), jnp.float32)
        return q, k, v

    @pytest.mark.parametrize("case", CASES)
    def test_grads_match_scan_vjp_and_oracle(self, case):
        *_, window, softcap, bq, bk = case
        q, k, v = self._inputs(case)

        def loss(bwd):
            def f(q, k, v):
                o = flash_gqa(q, k, v, window=window, softcap=softcap,
                              bq=bq, bk=bk, interpret=True, bwd=bwd)
                return jnp.sum(o.astype(jnp.float32) ** 2)
            return f

        def loss_ref(q, k, v):
            o = flash_gqa_ref(jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
                              jnp.swapaxes(v, 1, 2), window=window,
                              softcap=softcap)
            return jnp.sum(o.astype(jnp.float32) ** 2)

        g_kern = jax.grad(loss("kernel_interpret"), argnums=(0, 1, 2))(q, k, v)
        g_scan = jax.grad(loss("reference"), argnums=(0, 1, 2))(q, k, v)
        g_ref = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
        for a, b_, c in zip(g_kern, g_scan, g_ref):
            scale = float(jnp.max(jnp.abs(c))) + 1e-30
            np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                       rtol=1e-4, atol=1e-4 * scale)
            np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                       rtol=1e-4, atol=1e-4 * scale)

    def test_residual_forward_matches_plain_forward(self):
        """return_residual must not perturb the output, and the emitted
        LSE must equal the oracle's log-sum-exp of the masked scaled
        scores (the quantity both backward passes subtract)."""
        case = (1, 4, 2, 128, 32, 48, 30.0, 32, 32)
        *_, window, softcap, bq, bk = case
        q, k, v = (jnp.swapaxes(x, 1, 2) for x in self._inputs(case))
        out_plain = flash_gqa_pallas(q, k, v, window=window, softcap=softcap,
                                     bq=bq, bk=bk, interpret=True)
        out, lse = flash_gqa_pallas(q, k, v, window=window, softcap=softcap,
                                    bq=bq, bk=bk, interpret=True,
                                    return_residual=True)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(out_plain))

        b, h, s, d = q.shape
        g = h // k.shape[1]
        sc = d**-0.5
        scores = jnp.einsum("bhqd,bhkd->bhqk", q * sc,
                            jnp.repeat(k, g, axis=1))
        scores = softcap * jnp.tanh(scores / softcap)
        pos = jnp.arange(s)
        mask = (pos[None, :] <= pos[:, None]) & \
               ((pos[:, None] - pos[None, :]) < window)
        scores = jnp.where(mask[None, None], scores, -1e30)
        lse_ref = jax.scipy.special.logsumexp(scores, axis=-1)
        np.testing.assert_allclose(np.asarray(lse), np.asarray(lse_ref),
                                   rtol=1e-5, atol=1e-5)

    def test_bfloat16_grads(self):
        """bf16 inputs: the fused backward accumulates in f32 scratch and
        casts at the edges, like the forward."""
        case = (1, 4, 2, 128, 32, 48, None, 32, 32)
        *_, window, softcap, bq, bk = case
        q, k, v = (x.astype(jnp.bfloat16) for x in self._inputs(case))

        def loss(bwd):
            def f(q, k, v):
                o = flash_gqa(q, k, v, window=window, bq=bq, bk=bk,
                              interpret=True, bwd=bwd)
                return jnp.sum(o.astype(jnp.float32) ** 2)
            return f

        g_kern = jax.grad(loss("kernel_interpret"), argnums=(0, 1, 2))(q, k, v)
        g_scan = jax.grad(loss("reference"), argnums=(0, 1, 2))(q, k, v)
        for a, b_ in zip(g_kern, g_scan):
            assert a.dtype == jnp.bfloat16
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b_, np.float32),
                                       rtol=3e-2, atol=3e-2)
