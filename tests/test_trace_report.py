"""trace_report.py rendering against partial traces (ISSUE 10).

Sharded-output runs record no ``all_gather``/``replicate`` span and a run
may register histograms that never observe a value; the report script must
render those as ``—`` rather than raise.  The script is exercised through
its public entry points (``report_run`` / ``print_run`` /
``print_comparison``) on synthetic trace dirs.
"""
from __future__ import annotations

import importlib.util
import json
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO / "src"))

_spec = importlib.util.spec_from_file_location(
    "trace_report", REPO / "scripts" / "trace_report.py")
trace_report = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(trace_report)


def _write_run(tmp_path, name, phases, histograms=None):
    """Synthesize a traced run: span events + optional metrics snapshot."""
    run = tmp_path / name
    run.mkdir()
    with open(run / "events.jsonl", "w") as f:
        for phase, durs in phases.items():
            for d in durs:
                f.write(json.dumps({"k": "span", "name": phase, "dur": d})
                        + "\n")
    if histograms is not None:
        with open(run / "metrics.jsonl", "w") as f:
            f.write(json.dumps({"histograms": histograms, "gauges": {},
                                "counters": {}}) + "\n")
    (run / "meta.json").write_text(json.dumps(
        {"fingerprint": {"driver": "sync", "backend": "mesh",
                         "method": "pfedsop"}}))
    return run


class TestMissingPhaseRendering:
    def test_comparison_renders_dash_for_absent_phase(self, tmp_path, capsys):
        replicated = _write_run(tmp_path, "replicated", {
            "round": [900, 800], "client": [500, 450],
            "all_gather": [200, 180], "aggregate": [100, 90]})
        sharded = _write_run(tmp_path, "sharded", {
            "round": [700, 600], "client": [500, 450],
            "aggregate": [100, 90]})  # no all_gather span at all
        reps = [trace_report.report_run(r, top_k=3)
                for r in (replicated, sharded)]
        for rep in reps:
            trace_report.print_run(rep)
        trace_report.print_comparison(reps)
        out = capsys.readouterr().out
        assert "all_gather" in out
        assert "—" in out  # the sharded column renders a dash, not a crash

    def test_comparison_with_no_phases_at_all(self, tmp_path, capsys):
        empty = _write_run(tmp_path, "empty", {})
        rep = trace_report.report_run(empty, top_k=3)
        trace_report.print_run(rep)
        trace_report.print_comparison([rep, rep])
        assert rep["phases"] == {}

    def test_share_column_dash_without_round_phase(self, tmp_path, capsys):
        run = _write_run(tmp_path, "noround", {"client": [500, 450]})
        trace_report.print_run(trace_report.report_run(run, top_k=3))
        out = capsys.readouterr().out
        assert "client" in out and "—" in out


class TestHistogramRendering:
    def test_unobserved_histogram_renders(self):
        # Histogram.snapshot() of a never-observed histogram: min/max None
        h = {"edges": [0.0, 1.0], "counts": [0, 0, 0], "count": 0,
             "sum": 0.0, "min": None, "max": None}
        lines = trace_report._fmt_hist("beta", h)
        assert lines == ["  beta: n=0 mean=— min=— max=—"]

    def test_observed_histogram_renders_bars(self):
        h = {"edges": [0.0, 1.0], "counts": [0, 3, 1], "count": 4,
             "sum": 2.5, "min": 0.1, "max": 1.4}
        lines = trace_report._fmt_hist("beta", h)
        assert "n=4" in lines[0]
        assert any("#" in ln for ln in lines[1:])

    def test_print_run_with_unobserved_histogram(self, tmp_path, capsys):
        run = _write_run(
            tmp_path, "hist", {"round": [100, 90]},
            histograms={"fl.beta": {"edges": [0.0, 1.0],
                                    "counts": [0, 0, 0], "count": 0,
                                    "sum": 0.0, "min": None, "max": None}})
        trace_report.print_run(trace_report.report_run(run, top_k=3))
        out = capsys.readouterr().out
        assert "fl.beta: n=0" in out
