"""FL-runtime integration tests: every method runs; pFedSOP converges and
beats FedAvg under heterogeneity (the paper's core claim, miniaturised)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.resnet_cifar import SMALL_CNN
from repro.core.baselines import METHODS, FedRep
from repro.data import FederatedData, dirichlet_partition, make_class_conditional_images
from repro.fl import Federation, FLRunConfig
from repro.fl.runtime import masked_accuracy
from repro.models import cnn


CFG = SMALL_CNN


@pytest.fixture(scope="module")
def setup():
    images, labels = make_class_conditional_images(1500, CFG.n_classes, CFG.cnn_image_size, seed=0)
    parts = dirichlet_partition(labels, 10, alpha=0.15, seed=0)  # heterogeneous
    data = FederatedData.from_partition(images, labels, parts, seed=0)
    params = cnn.init_params(jax.random.PRNGKey(0), CFG)
    loss = lambda p, b: cnn.loss_fn(p, CFG, b)
    acc = masked_accuracy(lambda p, t: cnn.apply(p, CFG, t["images"]))
    return data, params, loss, acc


def _method(name):
    if name == "fedrep":
        return FedRep(head_predicate=lambda path: "fc_" in path)
    return METHODS[name]()


def test_scaffold_control_variates_update(setup):
    """SCAFFOLD: c_i moves after participation; server c tracks mean dc."""
    data, params, loss, acc = setup
    from repro.core.baselines import Scaffold

    m = Scaffold(lr=0.05)
    state = m.init_client(params)
    broadcast = m.init_server(params)
    rng = np.random.RandomState(0)
    batches = data.sample_round_batches(rng, [0], T=3, batch=8)
    b0 = jax.tree.map(lambda x: jnp.asarray(x[0]), batches)
    new_state, upload, metrics = m.client_round(loss, state, broadcast, b0)
    ci_norm = float(sum(jnp.sum(jnp.abs(l)) for l in jax.tree.leaves(new_state["c_i"])))
    assert ci_norm > 0 and np.isfinite(float(metrics["loss"]))
    stacked = jax.tree.map(lambda x: x[None], upload)
    nb = m.server_update(broadcast, stacked)
    c_norm = float(sum(jnp.sum(jnp.abs(l)) for l in jax.tree.leaves(nb["c"])))
    assert c_norm > 0


def test_fedexp_extrapolation_at_least_one(setup):
    """FedExP's server step size eta_g >= 1 (falls back to FedAvg)."""
    data, params, loss, acc = setup
    from repro.core.baselines import FedExP

    m = FedExP(lr=0.05)
    broadcast = m.init_server(params)
    rng = np.random.RandomState(0)
    batches = data.sample_round_batches(rng, [0, 1], T=2, batch=8)
    uploads = []
    for i in range(2):
        b = jax.tree.map(lambda x: jnp.asarray(x[i]), batches)
        _, up, _ = m.client_round(loss, {}, broadcast, b)
        uploads.append(up)
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *uploads)
    nb = m.server_update(broadcast, stacked)
    for a, b_ in zip(jax.tree.leaves(nb), jax.tree.leaves(broadcast)):
        assert np.all(np.isfinite(np.asarray(a, np.float32)))


@pytest.mark.parametrize("name", sorted(METHODS))
def test_method_runs_two_rounds(name, setup):
    data, params, loss, acc = setup
    run_cfg = FLRunConfig(n_clients=10, participation=0.3, rounds=2, batch=16,
                          local_iters=2, seed=1)
    fed = Federation(_method(name), loss, acc, params, data, run_cfg)
    hist = fed.run()
    assert len(hist["loss"]) == 2
    assert all(np.isfinite(v) for v in hist["loss"])
    assert all(0.0 <= a <= 1.0 for a in hist["acc"])


def test_pfedsop_converges_and_beats_fedavg(setup):
    """Miniature of the paper's Table II/Fig 2 claim: under heterogeneous
    partitioning, pFedSOP reaches higher personalized accuracy than FedAvg
    within the same round budget, and its training loss decreases."""
    data, params, loss, acc = setup
    run_cfg = FLRunConfig(n_clients=10, participation=0.4, rounds=8, batch=16,
                          local_iters=4, seed=0)
    results = {}
    for name in ["pfedsop", "fedavg"]:
        fed = Federation(_method(name), loss, acc, params, data, run_cfg)
        results[name] = fed.run()

    pf_hist, fa_hist = results["pfedsop"], results["fedavg"]
    assert pf_hist["loss"][-1] < pf_hist["loss"][0], "pFedSOP loss must decrease"
    assert pf_hist["mean_best_acc"] > fa_hist["mean_best_acc"], (
        pf_hist["mean_best_acc"], fa_hist["mean_best_acc"])


def test_partial_participation_tracks_latest_delta(setup):
    """A client absent for rounds keeps its latest delta (paper Sec. IV)."""
    data, params, loss, acc = setup
    run_cfg = FLRunConfig(n_clients=10, participation=0.2, rounds=4, batch=16,
                          local_iters=2, seed=3)
    fed = Federation(_method("pfedsop"), loss, acc, params, data, run_cfg)
    fed.run()
    seen = np.asarray(fed.client_states.rounds_seen)
    has = np.asarray(fed.client_states.has_delta)
    assert (seen > 0).sum() >= 2  # some clients participated
    np.testing.assert_array_equal(has, seen > 0)


def test_vmap_equals_sequential_clients(setup):
    """The vmap'd round == a python loop over clients (numerics check)."""
    data, params, loss, acc = setup
    method = _method("pfedsop")
    k = 4
    states = [method.init_client(params) for _ in range(k)]
    broadcast = method.init_server(params)
    rng = np.random.RandomState(0)
    ids = np.arange(k)
    batches = data.sample_round_batches(rng, ids, T=2, batch=8)

    # sequential
    seq_uploads = []
    for i in range(k):
        b_i = jax.tree.map(lambda x: jnp.asarray(x[i]), batches)
        _, up, _ = method.client_round(loss, states[i], broadcast, b_i)
        seq_uploads.append(up)

    # vmapped
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *states)
    _, vm_uploads, _ = jax.vmap(
        lambda s, b: method.client_round(loss, s, broadcast, b)
    )(stacked, jax.tree.map(jnp.asarray, batches))

    for i in range(k):
        for a, b in zip(jax.tree.leaves(seq_uploads[i]),
                        jax.tree.leaves(jax.tree.map(lambda x: x[i], vm_uploads))):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5)
