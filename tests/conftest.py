import numpy as np
import pytest

# NOTE: do NOT set --xla_force_host_platform_device_count here - the smoke
# tests and benches must see the single real CPU device.  Only
# repro/launch/dryrun.py (its own process) forces 512 placeholder devices.


@pytest.fixture(scope="session")
def rng():
    return np.random.RandomState(0)
