import os

import numpy as np
import pytest

# NOTE: do NOT set --xla_force_host_platform_device_count here - the smoke
# tests and benches must see the single real CPU device.  Only
# repro/launch/dryrun.py (its own process) forces 512 placeholder devices.


@pytest.fixture(scope="session")
def rng():
    return np.random.RandomState(0)


@pytest.fixture(autouse=True)
def _run_in_tmpdir(tmp_path, monkeypatch):
    """Run every test chdir'd into its own tmpdir.

    Anything a test (or code under test) writes relative to the CWD —
    results.json, mmap backing files, stray experiment artifacts — lands
    in pytest's per-test tmp tree instead of the repo checkout (ISSUE 7:
    no committed test artifacts).  Tests that need the repo root resolve
    it from ``__file__`` already.
    """
    monkeypatch.chdir(tmp_path)
    yield
