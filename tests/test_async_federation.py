"""Async federation subsystem tests (DESIGN.md §10).

The correctness anchor: the degenerate async configuration — every client
always online at uniform speed, concurrency = buffer_size = K' — must
reproduce the synchronous ``Federation`` loss/acc history BITWISE on the
same seed, under both engine backends (vmap in-process; a forced 4-device
shard_map mesh in a subprocess, mirroring tests/test_engine.py).  Plus:
the tau=0 identity of the method-level staleness hook, heterogeneous
scheduling behavior, determinism of the availability model, and §9
kernel-dispatch parity under the async driver.
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.resnet_cifar import SMALL_CNN
from repro.core.baselines import METHODS, staleness_weights
from repro.core import pfedsop as pf
from repro.data import FederatedData, dirichlet_partition, make_class_conditional_images
from repro.fl import (
    AsyncConfig,
    AsyncFederation,
    AvailabilityConfig,
    ClientAvailability,
    Federation,
    FLRunConfig,
    RoundScheduler,
)
from repro.fl.runtime import masked_accuracy
from repro.models import cnn

CFG = SMALL_CNN
REPO = Path(__file__).resolve().parents[1]

HETERO = AvailabilityConfig(speed="lognormal", sigma=1.0,
                            availability=0.3, mean_on=4.0)


@pytest.fixture(scope="module")
def setup():
    images, labels = make_class_conditional_images(800, CFG.n_classes,
                                                   CFG.cnn_image_size, seed=0)
    parts = dirichlet_partition(labels, 8, alpha=0.3, seed=0)
    data = FederatedData.from_partition(images, labels, parts, seed=0)
    params = cnn.init_params(jax.random.PRNGKey(0), CFG)
    loss = lambda p, b: cnn.loss_fn(p, CFG, b)
    acc = masked_accuracy(lambda p, t: cnn.apply(p, CFG, t["images"]))
    return data, params, loss, acc


def _run_cfg(rounds=3, backend="vmap", update_impl=""):
    return FLRunConfig(n_clients=8, participation=0.5, rounds=rounds,
                       batch=8, local_iters=2, seed=1, backend=backend,
                       update_impl=update_impl)


def _sync(setup, **kw):
    data, params, loss, acc = setup
    return Federation(METHODS[kw.pop("method", "pfedsop")](), loss, acc,
                      params, data, _run_cfg(**kw)).run()


def _async(setup, async_cfg=None, **kw):
    data, params, loss, acc = setup
    return AsyncFederation(METHODS[kw.pop("method", "pfedsop")](), loss, acc,
                           params, data, _run_cfg(**kw), async_cfg).run()


# ---------------------------------------------------------------------------
# Sync-degenerate bitwise parity (the subsystem's acceptance anchor)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("method", ["pfedsop", "fedavg"])
def test_degenerate_async_matches_sync_bitwise(setup, method):
    """Always-on clients, uniform speed, buffer_size = K' == lockstep sync.

    Exact ``==`` on purpose (cf. the single-device backend-parity canary
    in tests/test_engine.py): the async driver feeds identical operands
    to the SAME jitted phase programs, so any drift means the shared
    RoundPrograms seam broke — look at it, don't hide it in a tolerance.
    """
    h_sync = _sync(setup, method=method)
    h_async = _async(setup, method=method)  # AsyncConfig() defaults = degenerate
    assert h_sync["loss"] == h_async["loss"]
    assert h_sync["acc"] == h_async["acc"]
    assert h_sync["sim_time"] == h_async["sim_time"]
    assert h_async["staleness"] == [0.0] * len(h_async["loss"])
    assert h_async["engine"]["mode"] == "async"
    assert h_sync["mean_best_acc"] == h_async["mean_best_acc"]


def test_degenerate_async_matches_sync_kernel_impl(setup):
    """The degenerate equivalence also holds on the §9 kernel path."""
    h_sync = _sync(setup, update_impl="kernel_interpret")
    h_async = _async(setup, update_impl="kernel_interpret")
    assert h_sync["loss"] == h_async["loss"]
    assert h_sync["acc"] == h_async["acc"]


_MULTIDEV_SCRIPT = textwrap.dedent(
    """
    import jax, numpy as np
    assert len(jax.devices()) == 4, jax.devices()
    from repro.configs.resnet_cifar import SMALL_CNN as CFG
    from repro.core.baselines import METHODS
    from repro.data import (FederatedData, dirichlet_partition,
                            make_class_conditional_images)
    from repro.fl import AsyncFederation, Federation, FLRunConfig
    from repro.fl.runtime import masked_accuracy
    from repro.models import cnn

    images, labels = make_class_conditional_images(600, CFG.n_classes,
                                                   CFG.cnn_image_size, seed=0)
    parts = dirichlet_partition(labels, 8, alpha=0.3, seed=0)
    data = FederatedData.from_partition(images, labels, parts, seed=0)
    params = cnn.init_params(jax.random.PRNGKey(0), CFG)
    loss = lambda p, b: cnn.loss_fn(p, CFG, b)
    acc = masked_accuracy(lambda p, t: cnn.apply(p, CFG, t["images"]))

    cfg = FLRunConfig(n_clients=8, participation=0.5, rounds=2, batch=8,
                      local_iters=2, seed=1, backend="shard_map")
    h_sync = Federation(METHODS["pfedsop"](), loss, acc, params, data, cfg).run()
    h_async = AsyncFederation(METHODS["pfedsop"](), loss, acc, params, data,
                              cfg).run()
    assert h_sync["engine"]["shards"] == 4, h_sync["engine"]
    assert h_async["engine"]["shards"] == 4, h_async["engine"]
    assert h_sync["loss"] == h_async["loss"], (h_sync["loss"], h_async["loss"])
    assert h_sync["acc"] == h_async["acc"], (h_sync["acc"], h_async["acc"])
    print("ASYNC_MULTIDEV_PARITY_OK")
    """
)


def test_degenerate_parity_shard_map_multi_device():
    """Degenerate async == sync bitwise on a real 4-shard mesh.

    Subprocess: the XLA device count must be set before jax initialises,
    and the rest of the suite needs the single real CPU device.
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=4"
    ).strip()
    env["PYTHONPATH"] = str(REPO / "src") + os.pathsep + env.get("PYTHONPATH", "")
    res = subprocess.run(
        [sys.executable, "-c", _MULTIDEV_SCRIPT],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert res.returncode == 0, f"stdout:\n{res.stdout}\nstderr:\n{res.stderr}"
    assert "ASYNC_MULTIDEV_PARITY_OK" in res.stdout


# ---------------------------------------------------------------------------
# Staleness hook (FLMethod contract, DESIGN.md §10)
# ---------------------------------------------------------------------------


def _fake_uploads(params, n=3):
    return jax.tree.map(
        lambda x: jnp.stack([(i + 1.0) * x for i in range(n)]), params
    )


@pytest.mark.parametrize("name", ["fedavg", "fedprox", "fedrep", "local",
                                  "scaffold", "fedexp"])
def test_server_update_stale_tau_zero_is_identity(setup, name):
    """The default staleness hook with an all-fresh buffer is bitwise ==
    server_update (the identity the degenerate guarantee rests on)."""
    data, params, loss, acc = setup
    m = METHODS[name]() if name != "fedrep" else METHODS[name](
        head_predicate=lambda p: "fc_" in p)
    broadcast = m.init_server(params)
    ups = _fake_uploads(params)
    if name == "scaffold":
        ups = {"y": ups, "dc": jax.tree.map(lambda u: 0.1 * u, ups)}
    out_plain = m.server_update(broadcast, ups)
    out_stale = m.server_update_stale(broadcast, ups, jnp.zeros(3, jnp.int32))
    for a, b in zip(jax.tree.leaves(out_plain), jax.tree.leaves(out_stale)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_pfedsop_stale_tau_zero_is_identity(setup):
    data, params, loss, acc = setup
    m = METHODS["pfedsop"]()
    broadcast = {"delta": jax.tree.map(lambda x: 0.1 * x, params),
                 "has_delta": jnp.asarray(True)}
    ups = _fake_uploads(params)
    out_plain = m.server_update(broadcast, ups)
    out_stale = m.server_update_stale(broadcast, ups, jnp.zeros(3, jnp.int32))
    for a, b in zip(jax.tree.leaves(out_plain), jax.tree.leaves(out_stale)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_pfedsop_stale_blend_downweights_conflicting(setup):
    """A stale upload anti-aligned with the global delta is pulled toward
    it harder than an aligned one (the down-BLEND semantics)."""
    data, params, loss, acc = setup
    g = jax.tree.map(lambda x: jnp.ones_like(x), params)
    aligned = jax.tree.map(lambda x: 2.0 * x, g)
    conflicting = jax.tree.map(lambda x: -2.0 * x, g)
    s = pf.staleness_discount(jnp.asarray([4]), 0.5)[0]  # stale: s < 1
    bl_a = pf.stale_blend(aligned, g, s, lam=1.0)
    bl_c = pf.stale_blend(conflicting, g, s, lam=1.0)

    def dist(a, b):
        return float(sum(jnp.sum(jnp.abs(x - y))
                         for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b))))

    # the conflicting delta moves (much) more than the aligned one
    assert dist(bl_c, conflicting) > dist(bl_a, aligned)
    # fresh upload passes through bit-exactly regardless of angle
    fresh = pf.stale_blend(conflicting, g, jnp.float32(1.0), lam=1.0)
    for a, b in zip(jax.tree.leaves(fresh), jax.tree.leaves(conflicting)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_staleness_weights_mean_one():
    tau = jnp.asarray([0, 3, 7, 1], jnp.int32)
    w = staleness_weights(tau, 0.5)
    np.testing.assert_allclose(float(jnp.mean(w)), 1.0, rtol=1e-6)
    assert float(w[0]) > float(w[1]) > float(w[2])  # fresher -> heavier
    np.testing.assert_array_equal(
        np.asarray(staleness_weights(jnp.zeros(5, jnp.int32), 0.5)),
        np.ones(5, np.float32))


def test_stale_hook_required_only_for_async(setup):
    """server_update_stale is the async driver's hook: a sync-only custom
    method without it still passes the synchronous contract check, but
    AsyncFederation (and require_stale_hook validation) reject it."""
    from repro.fl.runtime import validate_method

    class NoStale:
        name = "nostale"

        def init_client(self, p): return {}
        def init_server(self, p): return p
        def client_round(self, *a): return None
        def server_update(self, *a): return None
        def eval_params(self, *a): return None

    validate_method(NoStale())  # the synchronous contract is satisfied
    with pytest.raises(TypeError, match="server_update_stale"):
        validate_method(NoStale(), require_stale_hook=True)
    data, params, loss, acc = setup
    with pytest.raises(TypeError, match="server_update_stale"):
        AsyncFederation(NoStale(), loss, acc, params, data, _run_cfg())


# ---------------------------------------------------------------------------
# Heterogeneous scheduling
# ---------------------------------------------------------------------------


def test_heterogeneous_async_runs_and_is_stale(setup):
    """Lognormal speeds + 30% availability: the event loop makes progress,
    sim_time is monotone, and buffered aggregation actually sees staleness."""
    acfg = AsyncConfig(buffer_size=2, concurrency=4, availability=HETERO)
    h = _async(setup, async_cfg=acfg, rounds=6)
    assert len(h["loss"]) == 6
    assert all(np.isfinite(v) for v in h["loss"])
    assert all(0.0 <= a <= 1.0 for a in h["acc"])
    sim = h["sim_time"]
    assert all(sim[i] <= sim[i + 1] for i in range(len(sim) - 1))
    assert min(h["staleness"]) >= 0.0
    assert max(h["staleness"]) > 0.0  # heterogeneity => stale uploads
    assert h["engine"]["buffer_size"] == 2
    # the described engine is one that actually ran: every recorded
    # cohort size is bounded by the in-flight cap
    assert h["engine"]["cohort_sizes"]
    assert max(h["engine"]["cohort_sizes"]) <= 4


def test_round_budget_caps_multi_flush_delivery(setup):
    """The drain stops at cfg.rounds: with buffer_size=1 a simultaneously
    delivered K'=4 cohort holds 4 flushes, and a budget that does not
    align with the cohort size must not overshoot (regression: rounds=6
    once returned 8 history entries and 8 applied server updates)."""
    acfg = AsyncConfig(buffer_size=1, concurrency=4)  # uniform speeds
    h = _async(setup, async_cfg=acfg, rounds=6)
    assert len(h["loss"]) == 6
    assert len(h["acc"]) == 6
    assert len(h["staleness"]) == 6
    assert len(h["sim_time"]) == 6


def test_heterogeneous_async_deterministic(setup):
    """Same seed -> identical histories (host RNG + seeded traces only)."""
    acfg = AsyncConfig(buffer_size=2, concurrency=4, availability=HETERO)
    h1 = _async(setup, async_cfg=acfg, rounds=4)
    h2 = _async(setup, async_cfg=acfg, rounds=4)
    assert h1["loss"] == h2["loss"]
    assert h1["sim_time"] == h2["sim_time"]
    assert h1["staleness"] == h2["staleness"]


def test_async_kernel_dispatch_parity_heterogeneous(setup):
    """The staleness-weighted path still dispatches through the fused
    pfedsop_update kernel (§9): reference vs kernel_interpret histories
    agree within fp32 reduction-order tolerance, and the host-side
    schedule (sim_time) is bit-identical (numerics never steer events)."""
    acfg = AsyncConfig(buffer_size=2, concurrency=4, availability=HETERO)
    h_ref = _async(setup, async_cfg=acfg, rounds=4, update_impl="reference")
    h_ker = _async(setup, async_cfg=acfg, rounds=4,
                   update_impl="kernel_interpret")
    np.testing.assert_allclose(h_ref["loss"], h_ker["loss"], rtol=1e-5,
                               atol=1e-6)
    assert h_ref["sim_time"] == h_ker["sim_time"]
    assert h_ref["staleness"] == h_ker["staleness"]


# ---------------------------------------------------------------------------
# Availability model + scheduler units
# ---------------------------------------------------------------------------


def test_availability_deterministic_and_seed_sensitive():
    a1 = ClientAvailability(HETERO, 16, seed=7)
    a2 = ClientAvailability(HETERO, 16, seed=7)
    a3 = ClientAvailability(HETERO, 16, seed=8)
    np.testing.assert_array_equal(a1.durations, a2.durations)
    assert not np.array_equal(a1.durations, a3.durations)
    probe = [(c, t) for c in range(16) for t in (0.0, 3.7, 11.2)]
    assert [a1.is_online(c, t) for c, t in probe] == \
           [a2.is_online(c, t) for c, t in probe]
    # query order must not matter (traces only ever extend forward)
    b1 = ClientAvailability(HETERO, 16, seed=7)
    assert [b1.is_online(c, t) for c, t in reversed(probe)] == \
           [a1.is_online(c, t) for c, t in reversed(probe)]


def test_availability_next_online_is_online():
    av = ClientAvailability(HETERO, 4, seed=3)
    for c in range(4):
        for t in (0.0, 5.0, 17.3):
            nt = av.next_online(c, t)
            assert nt >= t
            assert av.is_online(c, nt)


def test_availability_degenerate_always_on():
    av = ClientAvailability(AvailabilityConfig(), 4, seed=0)
    assert av.is_online(2, 123.4) and av.next_online(2, 123.4) == 123.4
    assert av.duration(2) == 1.0


def test_availability_validates_config():
    with pytest.raises(ValueError, match="availability"):
        ClientAvailability(AvailabilityConfig(availability=0.0), 4, 0)
    with pytest.raises(ValueError, match="speed"):
        ClientAvailability(AvailabilityConfig(speed="constant"), 4, 0)


def test_scheduler_degenerate_micro_cohort():
    """Uniform speeds: one dispatch group completes as ONE micro-cohort,
    in dispatch order, and the RNG draw matches the synchronous sampler."""
    av = ClientAvailability(AvailabilityConfig(), 8, seed=0)
    sched = RoundScheduler(av, concurrency=4)
    rng = np.random.RandomState(1)
    ids = sched.dispatch_group(0.0, rng)
    np.testing.assert_array_equal(
        ids, np.random.RandomState(1).choice(8, 4, replace=False))
    assert sched.free_slots() == 0
    assert len(sched.dispatch_group(0.0, rng)) == 0  # slots full
    t, done = sched.pop_completions()
    assert t == 1.0 and done == list(ids)
    assert sched.free_slots() == 4


def test_scheduler_excludes_inflight_and_offline():
    av = ClientAvailability(HETERO, 8, seed=5)
    sched = RoundScheduler(av, concurrency=8)
    online = [i for i in range(8) if av.is_online(i, 0.0)]
    ids = sched.dispatch_group(0.0, np.random.RandomState(0))
    assert set(ids.tolist()) <= set(online)
    # in-flight clients never re-dispatch until their completion delivers
    again = sched.dispatch_group(0.0, np.random.RandomState(1))
    assert not set(again.tolist()) & set(ids.tolist())
