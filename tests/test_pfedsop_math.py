"""Unit tests for the paper's math (Algorithms 1-3, Eqs. 11-19)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hyp_compat import given, hst, settings  # optional-hypothesis shim

from repro.core import pfedsop as pf
from repro.utils import pytree as pt


def _tree(key, scale=1.0):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w": jax.random.normal(k1, (7, 5)) * scale,
        "b": jax.random.normal(k2, (5,)) * scale,
        "nest": {"v": jax.random.normal(k3, (3, 2, 4)) * scale},
    }


class TestShermanMorrison:
    def test_matches_dense_inverse_oracle(self):
        """Eq. 18: the S-M closed form == explicit [dp dp^T + rho I]^{-1} dp."""
        rng = np.random.RandomState(0)
        for rho in [1.0, 0.1, 3.7]:
            dp = rng.randn(40).astype(np.float32)
            F = np.outer(dp, dp) + rho * np.eye(40)
            oracle = np.linalg.solve(F, dp)
            tree = {"a": jnp.asarray(dp[:25]), "b": jnp.asarray(dp[25:])}
            step = pf.sherman_morrison_step(tree, rho)
            got = np.concatenate([np.asarray(step["a"]), np.asarray(step["b"])])
            np.testing.assert_allclose(got, oracle, rtol=1e-5, atol=1e-6)

    def test_collapses_to_scalar_rescale(self):
        """F^{-1} dp == dp / (rho + ||dp||^2) (the rank-1 identity)."""
        tree = _tree(jax.random.PRNGKey(1))
        rho = 0.5
        step = pf.sherman_morrison_step(tree, rho)
        sq = float(pt.tree_sqnorm(tree))
        expect = pt.tree_scale(1.0 / (rho + sq), tree)
        for a, b in zip(jax.tree.leaves(step), jax.tree.leaves(expect)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5)

    @given(rho=hst.floats(0.01, 10.0), norm=hst.floats(1e-3, 1e3))
    @settings(max_examples=30, deadline=None)
    def test_step_never_exceeds_gradient_norm_over_rho(self, rho, norm):
        """||F^{-1}dp|| = ||dp||/(rho+||dp||^2) <= ||dp||/rho (damping)."""
        v = jnp.ones((16,)) * (norm / 4.0)
        step = pf.sherman_morrison_step({"v": v}, rho)
        assert float(pt.tree_norm(step)) <= float(pt.tree_norm({"v": v})) / rho + 1e-4


class TestGompertz:
    def test_range_and_monotonicity(self):
        """beta in (0,1); decreasing in the angle theta (Eq. 14)."""
        thetas = jnp.linspace(0.0, np.pi, 50)
        for lam in [0.5, 1.0, 2.5, 5.0]:
            beta = 1.0 - jnp.exp(-jnp.exp(-lam * (thetas - 1.0)))
            # mathematically (0,1); f32 saturates to the closed bounds at
            # steep lam, so assert the closed interval + strict interior at
            # the analytic midpoint
            assert float(beta.min()) >= 0.0 and float(beta.max()) <= 1.0
            mid = 1.0 - np.exp(-np.exp(-lam * (np.pi / 2 - 1.0)))
            assert 0.0 < mid < 1.0
            assert np.all(np.diff(np.asarray(beta)) <= 0)

    def test_aligned_updates_trust_global(self):
        """theta=0 (same direction) -> beta large; theta=pi -> beta small."""
        d = _tree(jax.random.PRNGKey(0))
        b_same, _ = pf.gompertz_weight(d, d, lam=1.0)
        b_opp, _ = pf.gompertz_weight(d, pt.tree_scale(-1.0, d), lam=1.0)
        assert float(b_same) > 0.9
        assert float(b_opp) < 0.3
        assert float(b_same) > float(b_opp)

    def test_zero_norm_guard(self):
        d = _tree(jax.random.PRNGKey(0))
        z = pt.tree_zeros_like(d)
        beta, aux = pf.gompertz_weight(z, d, lam=1.0)
        assert np.isfinite(float(beta))
        np.testing.assert_allclose(float(aux["theta"]), np.pi / 2, rtol=1e-5)

    @given(lam=hst.floats(0.1, 5.0), seed=hst.integers(0, 100))
    @settings(max_examples=25, deadline=None)
    def test_personalized_delta_is_convex_combination(self, lam, seed):
        k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
        dl, dg = _tree(k1), _tree(k2)
        dp, aux = pf.personalized_delta(dl, dg, lam)
        beta = float(aux["beta"])
        assert 0.0 < beta < 1.0
        for p, a, b in zip(jax.tree.leaves(dp), jax.tree.leaves(dl), jax.tree.leaves(dg)):
            expect = (1 - beta) * np.asarray(a) + beta * np.asarray(b)
            np.testing.assert_allclose(np.asarray(p), expect, rtol=1e-4, atol=1e-5)


class TestLocalSGD:
    def test_delta_equals_gradient_sum(self):
        """Eq. 11/16: (x0 - xT)/eta2 == sum of per-iteration gradients."""

        def loss_fn(p, batch):
            return jnp.mean((p["w"] @ batch["x"] - batch["y"]) ** 2)

        key = jax.random.PRNGKey(0)
        params = {"w": jax.random.normal(key, (3, 4))}
        batches = {
            "x": jax.random.normal(jax.random.fold_in(key, 1), (5, 4, 2)),
            "y": jax.random.normal(jax.random.fold_in(key, 2), (5, 3, 2)),
        }
        delta, final, _ = pf.local_sgd_delta(loss_fn, params, batches, eta2=0.01)

        # oracle: explicit loop accumulating grads
        p = params
        gsum = pt.tree_zeros_like(params)
        for t in range(5):
            b = jax.tree.map(lambda v: v[t], batches)
            g = jax.grad(loss_fn)(p, b)
            gsum = pt.tree_add(gsum, g)
            p = jax.tree.map(lambda x, gi: x - 0.01 * gi, p, g)
        np.testing.assert_allclose(
            np.asarray(delta["w"]), np.asarray(gsum["w"]), rtol=1e-4, atol=1e-5
        )
        np.testing.assert_allclose(np.asarray(final["w"]), np.asarray(p["w"]), rtol=1e-5)


class TestClientRound:
    def test_new_client_skips_personalization(self):
        def loss_fn(p, batch):
            return jnp.sum(p["w"] ** 2) + 0.0 * jnp.sum(batch)

        params = {"w": jnp.ones((4,))}
        state = pf.init_client_state(params)
        batches = jnp.zeros((3, 1))
        cfg = pf.PFedSOPConfig(eta1=0.5, eta2=0.1)
        gd = {"w": jnp.full((4,), 100.0)}  # would blow up if personalised
        new_state, delta, m = pf.client_round(
            loss_fn, state, gd, jnp.asarray(True), batches, cfg
        )
        # has_delta was False -> params must start from the stored init
        assert not bool(m["personalized"])
        assert np.all(np.isfinite(np.asarray(new_state.params["w"])))
        assert bool(new_state.has_delta)

    def test_convergence_on_quadratic(self):
        """pFedSOP drives a quadratic objective toward its optimum."""

        def loss_fn(p, batch):
            return 0.5 * jnp.sum((p["w"] - 3.0) ** 2) + 0.0 * jnp.sum(batch)

        params = {"w": jnp.zeros((8,))}
        state = pf.init_client_state(params)
        gd = {"w": jnp.zeros((8,))}
        has_g = jnp.asarray(False)
        cfg = pf.PFedSOPConfig(eta1=0.5, eta2=0.1, rho=1.0)
        batches = jnp.zeros((4, 1))
        for t in range(30):
            state, delta, _ = pf.client_round(loss_fn, state, gd, has_g, batches, cfg)
            gd, has_g = delta, jnp.asarray(True)  # 1-client federation
        err = float(jnp.max(jnp.abs(state.params["w"] - 3.0)))
        assert err < 0.05, err

    def test_ablation_no_pc_uses_global(self):
        params = {"w": jnp.zeros((4,))}
        dl = {"w": jnp.ones((4,))}
        dg = {"w": jnp.full((4,), 2.0)}
        cfg = pf.PFedSOPConfig(use_pc=False, eta1=1.0, rho=1.0)
        new, _ = pf.personalize(params, dl, dg, cfg)
        # step = dg / (rho + ||dg||^2) = 2/(1+16)
        np.testing.assert_allclose(np.asarray(new["w"]), -2.0 / 17.0, rtol=1e-5)


class TestServerAggregate:
    def test_mean_over_clients(self):
        deltas = {"w": jnp.arange(12.0).reshape(3, 4)}
        agg = pf.server_aggregate(deltas)
        np.testing.assert_allclose(np.asarray(agg["w"]), np.arange(12.0).reshape(3, 4).mean(0))


# ---------------------------------------------------------------------------
# Property hardening (ISSUE 7): fuzzed invariants of the Eq. 14/18 math and
# the staleness hooks.  The @given variants run in full wherever hypothesis
# is installed (CI: requirements-dev.txt); each has a deterministic
# companion sweeping a fixed grid so a bare interpreter still exercises the
# same invariant instead of skipping it.
# ---------------------------------------------------------------------------


def _angled_deltas(seed, theta, dim=24):
    """Two pytrees whose flattened angle is exactly ``theta``: dg along a
    random unit vector u, dl = cos(theta) u + sin(theta) v with v ⟂ u."""
    rng = np.random.RandomState(seed)
    u = rng.randn(dim).astype(np.float32)
    u /= np.linalg.norm(u)
    v = rng.randn(dim).astype(np.float32)
    v -= u * (u @ v)
    v /= np.linalg.norm(v)
    dl = np.cos(theta) * u + np.sin(theta) * v
    split = dim // 2
    tree = lambda x: {"a": jnp.asarray(x[:split]), "b": jnp.asarray(x[split:])}
    return tree(dl), tree(u)


def _gompertz_invariants(lam, seed):
    thetas = np.linspace(0.0, np.pi, 9)
    betas = []
    for th in thetas:
        dl, dg = _angled_deltas(seed, th)
        beta, aux = pf.gompertz_weight(dl, dg, lam=lam)
        beta = float(beta)
        # bounded in (0, 1]: Gompertz is analytically (0, 1); f32 may
        # saturate the upper bound at steep lam, never the lower
        assert 0.0 < beta <= 1.0, (lam, th, beta)
        np.testing.assert_allclose(float(aux["theta"]), th, atol=1e-3)
        betas.append(beta)
    # monotone non-increasing in the angle (f32 tolerance at saturation)
    assert np.all(np.diff(betas) <= 1e-6), (lam, seed, betas)


class TestGompertzProperties:
    def test_bounds_and_monotonicity_grid(self):
        for lam in [0.1, 0.5, 1.0, 2.5, 5.0]:
            for seed in range(5):
                _gompertz_invariants(lam, seed)

    @given(lam=hst.floats(0.05, 8.0), seed=hst.integers(0, 10_000))
    @settings(max_examples=50, deadline=None)
    def test_bounds_and_monotonicity_fuzzed(self, lam, seed):
        _gompertz_invariants(lam, seed)

    def test_shape_dtype_fuzz_grid(self):
        """Eq. 14/18 invariants hold across leaf shapes, dtypes and seeds."""
        for seed in range(4):
            key = jax.random.PRNGKey(seed)
            for dtype in [jnp.float32, jnp.float16]:
                for shape in [(3,), (4, 5), (2, 3, 4)]:
                    k1, k2 = jax.random.split(jax.random.fold_in(key, hash(shape) % 97))
                    dl = {"x": jax.random.normal(k1, shape, dtype)}
                    dg = {"x": jax.random.normal(k2, shape, dtype)}
                    beta, _ = pf.gompertz_weight(dl, dg, lam=1.0)
                    assert 0.0 < float(beta) <= 1.0, (dtype, shape, seed)
                    step = pf.sherman_morrison_step(dl, rho=1.0)
                    assert step["x"].shape == shape
                    assert np.all(np.isfinite(np.asarray(step["x"], np.float32)))
                    # rank-1 identity: step = dp / (rho + ||dp||^2)
                    sq = float(pt.tree_sqnorm(dl))
                    np.testing.assert_allclose(
                        np.asarray(step["x"], np.float32),
                        np.asarray(dl["x"], np.float32) / (1.0 + sq),
                        rtol=5e-3, atol=1e-4)

    @given(seed=hst.integers(0, 10_000), dim=hst.integers(1, 64),
           scale=hst.floats(1e-3, 1e3))
    @settings(max_examples=50, deadline=None)
    def test_sherman_morrison_rank1_identity_fuzzed(self, seed, dim, scale):
        rng = np.random.RandomState(seed)
        dp = {"v": jnp.asarray(rng.randn(dim).astype(np.float32) * scale)}
        step = pf.sherman_morrison_step(dp, rho=1.0)
        sq = float(pt.tree_sqnorm(dp))
        np.testing.assert_allclose(np.asarray(step["v"]),
                                   np.asarray(dp["v"]) / (1.0 + sq),
                                   rtol=1e-4, atol=1e-6)


class TestStalenessProperties:
    def test_discount_tau0_is_exactly_one(self):
        """(1 + 0)^(-e) == 1.0 in IEEE for every exponent: the bitwise
        anchor of the async sync-degenerate guarantee."""
        for exp in [0.0, 0.5, 1.0, 2.0, 7.3]:
            s = pf.staleness_discount(jnp.zeros((5,), jnp.int32), exp)
            assert np.asarray(s).tolist() == [1.0] * 5

    def test_stale_blend_tau0_bitwise_identity(self):
        """discount = 1 -> c = 0 -> blend returns the upload bit-exactly."""
        for seed in range(6):
            k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
            up, gd = _tree(k1, scale=3.0), _tree(k2)
            out = pf.stale_blend(up, gd, discount=jnp.float32(1.0), lam=1.0)
            for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(up)):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_stale_blend_between_upload_and_global(self):
        """0 < discount < 1: each leaf lies on the [upload, global] segment."""
        k1, k2 = jax.random.split(jax.random.PRNGKey(3))
        up, gd = _tree(k1), _tree(k2)
        out = pf.stale_blend(up, gd, discount=jnp.float32(0.25), lam=1.0)
        for o, a, b in zip(jax.tree.leaves(out), jax.tree.leaves(up),
                           jax.tree.leaves(gd)):
            o, a, b = (np.asarray(x, np.float64) for x in (o, a, b))
            lo, hi = np.minimum(a, b), np.maximum(a, b)
            assert np.all(o >= lo - 1e-6) and np.all(o <= hi + 1e-6)

    def test_staleness_weights_mean_one_grid(self):
        from repro.core.baselines import staleness_weights
        for seed in range(5):
            rng = np.random.RandomState(seed)
            tau = jnp.asarray(rng.randint(0, 20, size=8), jnp.int32)
            for exp in [0.5, 1.0, 2.0]:
                w = np.asarray(staleness_weights(tau, exp), np.float64)
                assert np.all(w > 0)
                np.testing.assert_allclose(w.mean(), 1.0, rtol=1e-6)
        # all-fresh buffer: weights are EXACTLY ones (bitwise identity)
        w = np.asarray(staleness_weights(jnp.zeros((4,), jnp.int32), 1.0))
        assert w.tolist() == [1.0] * 4

    @given(seed=hst.integers(0, 10_000), n=hst.integers(1, 32),
           exp=hst.floats(0.0, 5.0))
    @settings(max_examples=50, deadline=None)
    def test_staleness_weights_mean_one_fuzzed(self, seed, n, exp):
        from repro.core.baselines import staleness_weights
        rng = np.random.RandomState(seed)
        tau = jnp.asarray(rng.randint(0, 50, size=n), jnp.int32)
        w = np.asarray(staleness_weights(tau, exp), np.float64)
        assert np.all(w > 0)
        np.testing.assert_allclose(w.mean(), 1.0, rtol=1e-5)
