"""Roofline machinery unit tests (HLO parsing + analytic FLOPs)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import INPUT_SHAPES, get_config
from repro.launch.roofline import (
    active_param_count,
    collective_bytes_from_hlo,
    model_flops,
    roofline_terms,
)

HLO_SNIPPET = """
HloModule test
ENTRY %main {
  %ar = bf16[4,128]{1,0} all-reduce(bf16[4,128]{1,0} %x), replica_groups={}
  %ag.1 = f32[16,256]{1,0} all-gather(f32[2,256]{1,0} %y), dimensions={0}
  %ars = (f32[8]{0}, f32[8]{0}) all-reduce-start(f32[8]{0} %a, f32[8]{0} %b)
  %ard = f32[8]{0} all-reduce-done(%ars)
  %cp = u32[64]{0} collective-permute(u32[64]{0} %z), source_target_pairs={{0,1}}
  %normal = f32[32,32]{1,0} dot(f32[32,32]{1,0} %p, f32[32,32]{1,0} %q)
}
"""


class TestCollectiveParse:
    def test_census(self):
        out = collective_bytes_from_hlo(HLO_SNIPPET)
        assert out["all-reduce"]["count"] == 2  # plain + -start (not -done)
        assert out["all-reduce"]["bytes"] == 4 * 128 * 2 + 2 * 8 * 4
        assert out["all-gather"]["bytes"] == 16 * 256 * 4
        assert out["collective-permute"]["bytes"] == 64 * 4
        assert "dot" not in out

    def test_roofline_terms_dominance(self):
        record = {
            "cost_analysis": {"flops": 197e12, "bytes accessed": 819e9 * 2},
            "collectives": {"all-reduce": {"bytes": 50e9 * 0.5, "count": 1}},
        }
        rl = roofline_terms(record, n_devices=4)
        np.testing.assert_allclose(rl["compute_s"], 1.0)
        np.testing.assert_allclose(rl["memory_s"], 2.0)
        np.testing.assert_allclose(rl["collective_s"], 0.5)
        assert rl["dominant"] == "memory"


class TestModelFlops:
    def test_active_params_moe_counts_topk_only(self):
        """MoE active params use top_k experts, not all E."""
        o = get_config("olmoe-1b-7b")
        n_active = active_param_count(o)
        # FFN active share: 3*d*ff*k = 3*2048*1024*8 per layer
        ffn = 3 * 2048 * 1024 * 8
        attn = 2048 * 16 * 128 * 2 + 2 * 2048 * 16 * 128
        per_layer = ffn + attn + 2048 * 64  # + router
        np.testing.assert_allclose(n_active, per_layer * 16, rtol=1e-6)

    def test_dense_flops_scale_with_tokens(self):
        g = get_config("granite-3-2b")
        f_train = model_flops(g, INPUT_SHAPES["train_4k"])
        f_decode = model_flops(g, INPUT_SHAPES["decode_32k"])
        # train: 6*N*(256*4096) tokens; decode: 2*N*128 tokens
        assert f_train / f_decode == (6 * 256 * 4096) / (2 * 128)

    def test_ssm_params_positive(self):
        m = get_config("mamba2-2.7b")
        n = active_param_count(m)
        assert 2e9 < n < 4e9  # "2.7b"-class
