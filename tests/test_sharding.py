"""Sharding-rule and launch-layer unit tests (single real CPU device; the
512-device production lowering lives in repro/launch/dryrun.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import INPUT_SHAPES, get_config
from repro.launch import sharding as sh
from repro.launch import steps as st


def _find(tree_specs, *names):
    """Fetch the spec of the leaf whose path ends with the given names."""
    out = []

    def walk(path, node):
        if isinstance(node, P):
            if list(names) == [str(p) for p in path][-len(names):]:
                out.append(node)
            return
        if isinstance(node, dict):
            for k, v in node.items():
                walk(path + [k], v)
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                walk(path + [str(i)], v)

    walk([], tree_specs)
    assert out, f"no leaf ending in {names}"
    return out[0]


class TestParamRules:
    def test_dense_arch_rules(self):
        cfg = get_config("granite-3-2b")
        params = st.abstract_params(cfg)
        specs = sh.param_pspecs(params, msize=16)
        # granite vocab 49155 is NOT divisible by 16 -> replicated (the
        # rules never introduce GSPMD padding); gemma2's 256000 shards.
        assert _find(specs, "embed") == P(None, None)
        g2 = sh.param_pspecs(st.abstract_params(get_config("gemma2-9b")), msize=16)
        assert _find(g2, "embed") == P("model", None)
        # wq (D,H=32,hd) under the pattern stack axis: heads sharded
        assert tuple(_find(specs, "attn", "wq")) == (None, None, "model", None)
        # mlp wi (D,F): F sharded; wo (F,D): F sharded
        assert _find(specs, "mlp", "wi_gate")[-1] == "model"
        assert _find(specs, "mlp", "wo")[-2] == "model"
        # norms replicated
        assert _find(specs, "ln1", "scale") == P(None, None)

    def test_gemma3_few_heads_fall_back(self):
        """gemma3-1b: H=4, KV=1 not divisible by 16 -> hd axis (256) instead."""
        cfg = get_config("gemma3-1b")
        params = st.abstract_params(cfg)
        specs = sh.param_pspecs(params, msize=16)
        assert _find(specs, "attn", "wq") == P(None, None, None, "model")
        assert _find(specs, "attn", "wk") == P(None, None, None, "model")

    def test_moe_expert_parallel(self):
        cfg = get_config("olmoe-1b-7b")
        specs = sh.param_pspecs(st.abstract_params(cfg), msize=16)
        assert _find(specs, "moe", "wi_gate") == P(None, "model", None, None)
        assert _find(specs, "moe", "router") == P(None, None, None)

    def test_client_axis_prefix(self):
        cfg = get_config("granite-3-2b")
        specs = sh.param_pspecs(st.abstract_params(cfg), msize=16,
                                client=True, client_axis="pod")
        assert _find(specs, "embed")[0] == "pod"

    def test_cache_rules(self):
        cfg = get_config("gemma2-9b")
        shape = INPUT_SHAPES["decode_32k"]
        caches = st.abstract_caches(cfg, shape.global_batch, shape.seq_len)
        specs = sh.cache_pspecs(caches, dsize=16, msize=16)
        k_spec = _find(specs, "k")
        # stacked pattern leaf: (n_rep, B, cap, KV, hd)
        assert k_spec == P(None, "data", "model", None, None)

    def test_ssm_cache_rules(self):
        cfg = get_config("mamba2-2.7b")
        caches = st.abstract_caches(cfg, 128, 32768)
        specs = sh.cache_pspecs(caches, dsize=16, msize=16)
        assert _find(specs, "state") == P(None, "data", "model", None, None)


class TestInputSpecs:
    @pytest.mark.parametrize("shape_name", list(INPUT_SHAPES))
    @pytest.mark.parametrize("arch", ["gemma3-1b", "mamba2-2.7b", "olmoe-1b-7b",
                                      "musicgen-large", "internvl2-2b"])
    def test_specs_build_without_allocation(self, arch, shape_name):
        cfg = get_config(arch)
        shape = INPUT_SHAPES[shape_name]
        specs = st.input_specs(cfg, shape, n_clients=2)
        for leaf in jax.tree.leaves(specs):
            assert isinstance(leaf, jax.ShapeDtypeStruct)

    def test_train_batch_layout(self):
        cfg = get_config("granite-3-2b")
        specs = st.input_specs(cfg, INPUT_SHAPES["train_4k"], n_clients=2,
                               micro_batch=32)
        toks = specs["batches"]["tokens"]
        assert toks.shape == (2, 8, 32, 4096)  # (clients, T, micro_b, S)

    def test_vlm_text_plus_patches(self):
        cfg = get_config("internvl2-2b")
        specs = st.input_specs(cfg, INPUT_SHAPES["prefill_32k"], n_clients=1)
        t = specs["batch"]["tokens"].shape
        p = specs["batch"]["patch_embeds"].shape
        assert t[-1] + p[-2] == 32768  # text + patches == seq_len


class TestStepsOnHostMesh:
    """Run the sharded step code end-to-end on a 1x1 mesh with a reduced
    config - exercises the exact jit/sharding path of the dry-run with
    real numerics."""

    def test_train_step_runs_and_is_finite(self):
        from repro.launch.mesh import make_host_mesh

        cfg = get_config("granite-3-2b", reduced=True)
        mesh = make_host_mesh()
        shape = INPUT_SHAPES["train_4k"]
        step = st.make_train_step(cfg, shape)

        from repro.models import transformer as tf

        params = tf.init_params(jax.random.PRNGKey(0), cfg)
        zeros = jax.tree.map(jnp.zeros_like, params)
        state = jax.tree.map(lambda x: x[None], {"params": params, "delta": zeros})
        gd = zeros
        key = jax.random.PRNGKey(1)
        toks = jax.random.randint(key, (1, 2, 4, 64), 0, cfg.vocab_size)
        batches = {"tokens": toks, "labels": toks}
        with mesh:
            new_state, new_gd, loss = jax.jit(step)(state, gd, batches)
        assert np.isfinite(float(loss))
        for leaf in jax.tree.leaves(new_state):
            assert np.all(np.isfinite(np.asarray(leaf, np.float32)))

    def test_serve_step_runs(self):
        from repro.models import transformer as tf

        cfg = get_config("gemma3-1b", reduced=True)
        shape = INPUT_SHAPES["decode_32k"]
        step = st.make_serve_step(cfg, shape)
        params = tf.init_params(jax.random.PRNGKey(0), cfg)
        params1 = jax.tree.map(lambda x: x[None], params)
        caches = tf.init_caches(cfg, 2, 32)
        caches1 = jax.tree.map(lambda x: x[None], caches)
        batch = {"tokens": jnp.zeros((1, 2, 1), jnp.int32)}
        token, new_caches = jax.jit(step)(params1, batch, jnp.asarray(0, jnp.int32), caches1)
        assert token.shape == (1, 2, 1)
        assert np.all(np.asarray(token) >= 0)
