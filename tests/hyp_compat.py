"""Optional-hypothesis shim: property tests skip cleanly when absent.

The tier-1 suite must collect and run on a bare interpreter (no dev
deps installed).  Test modules import ``given``/``settings``/``hst`` from
here instead of ``hypothesis`` directly:

    from hyp_compat import given, settings, hst

With hypothesis installed (``pip install -r requirements-dev.txt``) these
are the real objects and the property tests run in full.  Without it,
``given`` rewrites the test into a zero-fixture function that calls
``pytest.skip`` at run time, ``settings`` is an identity decorator, and
``hst`` is a stub whose strategy constructors return inert placeholders
(they are only ever passed to the stub ``given``).
"""
import pytest

try:
    from hypothesis import given, settings, strategies as hst  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            def skipped(*args, **kwargs):
                pytest.skip("hypothesis not installed (see requirements-dev.txt)")

            skipped.__name__ = fn.__name__
            skipped.__doc__ = fn.__doc__
            return skipped

        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _StrategyStub:
        def __getattr__(self, name):
            return lambda *a, **k: None

    hst = _StrategyStub()
