"""Sharded-at-rest round loop parity grid (DESIGN.md §11, ISSUE 10).

``FLRunConfig.output_sharding="sharded"`` keeps engine outputs
client-sharded through the round boundary and lowers Eq. 13's server
aggregation into the sharded program; the contract is that this is a pure
layout change — loss/accuracy histories stay **bitwise** identical to
``"replicated"`` on the same backend, across {shard_map, mesh} ×
{sync, async} × {device, host} cohort stores, with the interpret kernel
on the hot path.  The data-axis local SGD rides the same grid:
``grad_chunks`` equal to the mesh's data-axis size shards each client's
batch over ``data`` with bitwise-identical histories vs the in-body
chunk path.

Subprocess: the 8-device (2,2,2) mesh must be forced before jax
initialises (cf. tests/test_multipod.py).
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]

_SHARDED_SCRIPT = textwrap.dedent(
    """
    import dataclasses
    import jax
    assert len(jax.devices()) == 8, jax.devices()

    from repro.configs.resnet_cifar import SMALL_CNN as CFG
    from repro.core.baselines import METHODS
    from repro.data import (FederatedData, dirichlet_partition,
                            make_class_conditional_images)
    from repro.fl import AsyncFederation, Federation, FLRunConfig, StoreConfig
    from repro.fl.runtime import masked_accuracy
    from repro.models import cnn

    images, labels = make_class_conditional_images(600, CFG.n_classes,
                                                   CFG.cnn_image_size, seed=0)
    parts = dirichlet_partition(labels, 8, alpha=0.3, seed=0)
    data = FederatedData.from_partition(images, labels, parts, seed=0)
    params = cnn.init_params(jax.random.PRNGKey(0), CFG)
    loss = lambda p, b: cnn.loss_fn(p, CFG, b)
    acc = masked_accuracy(lambda p, t: cnn.apply(p, CFG, t["images"]))

    def cfg(backend, mesh="", **kw):
        # rounds=3 so re-participating clients personalize (the batched
        # pfedsop_update kernel is live from round 2 on); K'=4 divides
        # the 2 pods and the 4-way shard_map split
        return FLRunConfig(n_clients=8, participation=0.5, rounds=3,
                           batch=8, local_iters=2, seed=1, backend=backend,
                           mesh=mesh, update_impl="kernel_interpret", **kw)

    def run(driver, c):
        method = METHODS["pfedsop"]()
        fed = (Federation if driver == "sync" else AsyncFederation)(
            method, loss, acc, params, data, c)
        return fed.run()

    # -- sharded == replicated, same backend, full grid -------------------
    for backend, mesh_spec in [("shard_map", ""), ("mesh", "pods:2x2x2")]:
        for driver in ["sync", "async"]:
            for store in ["device", "host"]:
                base = cfg(backend, mesh_spec,
                           store=StoreConfig(kind=store))
                h_rep = run(driver, base)
                h_sh = run(driver, dataclasses.replace(
                    base, output_sharding="sharded"))
                key = (backend, driver, store)
                assert h_rep["loss"] == h_sh["loss"], (key, h_rep["loss"],
                                                       h_sh["loss"])
                assert h_rep["acc"] == h_sh["acc"], key
                print("GRID_OK", backend, driver, store)
    print("SHARDED_GRID_BITWISE_OK")

    # -- data-axis local SGD: in-body chunks == data-axis sharded ---------
    h_chunk_ref = run("sync", cfg("vmap", grad_chunks=2))
    h_chunk = run("sync", cfg("mesh", "pods:2x2x2", grad_chunks=2,
                              output_sharding="sharded"))
    assert h_chunk_ref["loss"] == h_chunk["loss"], (h_chunk_ref["loss"],
                                                    h_chunk["loss"])
    assert h_chunk_ref["acc"] == h_chunk["acc"]
    # the chunked gradient is a real semantic knob, not a no-op
    h_plain = run("sync", cfg("vmap"))
    assert h_chunk_ref["loss"] != h_plain["loss"]
    print("DATA_AXIS_CHUNKS_BITWISE_OK")
    """
)


def test_output_sharding_parity_forced_8_devices():
    """sharded == replicated bitwise across {shard_map, mesh} x
    {sync, async} x {device, host} stores, plus data-axis grad-chunk
    parity, in one subprocess (amortizes the forced-device compiles)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=8"
    ).strip()
    env["PYTHONPATH"] = str(REPO / "src") + os.pathsep + env.get("PYTHONPATH", "")
    res = subprocess.run(
        [sys.executable, "-c", _SHARDED_SCRIPT],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert res.returncode == 0, f"stdout:\n{res.stdout}\nstderr:\n{res.stderr}"
    for marker in ["SHARDED_GRID_BITWISE_OK", "DATA_AXIS_CHUNKS_BITWISE_OK"]:
        assert marker in res.stdout, res.stdout
