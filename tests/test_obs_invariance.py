"""The §13 hard contract: observability NEVER changes traced values.

For every engine backend x driver combination — {vmap, shard_map,
multi-pod mesh} x {sync, async} — the full training history of a traced
run (phase level, metrics on) must be bitwise identical to the untraced
run, except ``round_time`` (wall clock is the one documented cost of the
``timed`` block-until-ready boundaries).  Subprocess on a forced
8-device mesh, like tests/test_multipod.py: the mesh backend needs the
device count forced before jax initialises.
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]

_INVARIANCE_SCRIPT = textwrap.dedent(
    """
    import tempfile
    from pathlib import Path

    import jax
    assert len(jax.devices()) == 8, jax.devices()

    from repro.configs.resnet_cifar import SMALL_CNN as CFG
    from repro.core.baselines import METHODS
    from repro.data import (FederatedData, dirichlet_partition,
                            make_class_conditional_images)
    from repro.fl import AsyncFederation, Federation, FLRunConfig
    from repro.fl.runtime import masked_accuracy
    from repro.models import cnn
    from repro.obs import ObsConfig, read_events, read_metrics

    images, labels = make_class_conditional_images(600, CFG.n_classes,
                                                   CFG.cnn_image_size, seed=0)
    parts = dirichlet_partition(labels, 8, alpha=0.3, seed=0)
    data = FederatedData.from_partition(images, labels, parts, seed=0)
    params = cnn.init_params(jax.random.PRNGKey(0), CFG)
    loss = lambda p, b: cnn.loss_fn(p, CFG, b)
    acc = masked_accuracy(lambda p, t: cnn.apply(p, CFG, t["images"]))
    tmp = Path(tempfile.mkdtemp())

    def run(backend, mesh, driver, obs):
        cfg = FLRunConfig(n_clients=8, participation=0.5, rounds=2, batch=8,
                          local_iters=2, seed=1, backend=backend, mesh=mesh,
                          update_impl="kernel_interpret", obs=obs)
        cls = AsyncFederation if driver == "async" else Federation
        return cls(METHODS["pfedsop"](), loss, acc, params, data, cfg).run()

    for backend, mesh in [("vmap", ""), ("shard_map", ""),
                          ("mesh", "pods:2x2x2")]:
        for driver in ["sync", "async"]:
            tdir = tmp / f"{backend}_{driver}"
            h_off = run(backend, mesh, driver, None)
            h_on = run(backend, mesh, driver,
                       ObsConfig(trace_dir=str(tdir), level="phase",
                                 quiet=True))
            for key in h_off:
                if key == "round_time":
                    continue
                assert h_off[key] == h_on[key], (
                    backend, driver, key, h_off[key], h_on[key])
            # the traced run actually traced: spans + per-round metrics
            evs = read_events(tdir)
            assert any(e.get("k") == "span" and e["name"] == "client"
                       for e in evs), (backend, driver)
            snaps = read_metrics(tdir / "metrics.jsonl")
            assert len(snaps) == 2, (backend, driver, len(snaps))
            assert (tdir / "trace.json").exists()
            print(f"INVARIANT_OK {backend}/{driver}")
    print("ALL_INVARIANT_OK")
    """
)


def test_traced_equals_untraced_all_backends_forced_8_devices():
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=8"
    ).strip()
    env["PYTHONPATH"] = str(REPO / "src") + os.pathsep + env.get("PYTHONPATH", "")
    res = subprocess.run(
        [sys.executable, "-c", _INVARIANCE_SCRIPT],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert res.returncode == 0, f"stdout:\n{res.stdout}\nstderr:\n{res.stderr}"
    for backend in ["vmap", "shard_map", "mesh"]:
        for driver in ["sync", "async"]:
            assert f"INVARIANT_OK {backend}/{driver}" in res.stdout, res.stdout
    assert "ALL_INVARIANT_OK" in res.stdout
