"""Unit tests for the calibration composition math (no device lowering -
the lowering path is exercised by launch/calibrate.py itself)."""
import numpy as np
import pytest

from repro.configs import INPUT_SHAPES, get_config
from repro.launch.calibrate import _unrolled_cfg


class TestUnrolledCfg:
    def test_single_copy_structure(self):
        cfg = get_config("gemma3-1b")
        u = _unrolled_cfg(cfg, INPUT_SHAPES["train_4k"], 1)
        assert u.n_rep == 0 and not u.pattern
        assert len(u.tail) == len(cfg.pattern)
        assert u.n_layers == len(cfg.pattern)
        # all loop trip counts forced to 1
        assert u.attn_q_block == 4096
        assert u.ssm_chunk == 4096

    def test_two_copies_doubles_tail(self):
        cfg = get_config("zamba2-2.7b")
        u1 = _unrolled_cfg(cfg, INPUT_SHAPES["prefill_32k"], 1)
        u2 = _unrolled_cfg(cfg, INPUT_SHAPES["prefill_32k"], 2)
        assert len(u2.tail) == 2 * len(u1.tail)
        # shared_attn entries preserved (params stay shared via params["shared"])
        kinds = [s.kind for s in u2.tail]
        assert kinds.count("shared_attn") == 2

    def test_chunk_override_sets_unroll(self):
        cfg = get_config("mamba2-2.7b")
        u = _unrolled_cfg(cfg, INPUT_SHAPES["train_4k"], 1, ssm_chunk=256)
        assert u.ssm_chunk == 256
        assert u.ssm_scan_unroll == 4096 // 256

    def test_composition_formula(self):
        """total = T*(fixed + unit*(n_rep + tail/|pattern|)) with
        fixed = 2A - B, unit = B - A reproduces exact linear costs."""
        # synthetic: cost(n_copies) = fixed + unit*n_copies
        fixed, unit = 7.0, 3.0
        a = fixed + unit * 1
        b = fixed + unit * 2
        u_est = b - a
        f_est = a - u_est
        np.testing.assert_allclose(u_est, unit)
        np.testing.assert_allclose(f_est, fixed)
        n_rep, tail_frac, t_iters = 21, 0.0, 8
        total = t_iters * (f_est + u_est * (n_rep + tail_frac))
        np.testing.assert_allclose(total, 8 * (7 + 3 * 21))


class TestLongContextVariant:
    def test_window_caps_for_dense(self):
        from repro.models.transformer import apply_long_context

        cfg = get_config("gemma2-9b")
        lc = apply_long_context(cfg)
        assert all(s.window is not None and s.window <= 4096 for s in lc.layers)

    def test_native_archs_unchanged(self):
        from repro.models.transformer import apply_long_context

        for name in ["mamba2-2.7b", "zamba2-2.7b"]:
            cfg = get_config(name)
            assert apply_long_context(cfg) is cfg
