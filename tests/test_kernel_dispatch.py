"""Kernel-dispatch layer tests (DESIGN.md §9).

The parity guarantee: the fused Pallas update impl (interpret mode on CPU)
must reproduce the pytree reference impl within fp32 reduction-order
tolerance — per personalize() call, and end-to-end as identical federation
round histories on the same seed under both engine backends (the 4-device
``ShardMapBackend`` case runs in a subprocess, cf. tests/test_engine.py).
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.resnet_cifar import SMALL_CNN
from repro.core import pfedsop as pf
from repro.core.baselines import METHODS, PFedSOP
from repro.data import FederatedData, dirichlet_partition, make_class_conditional_images
from repro.fl import Federation, FLRunConfig, override_update_impl
from repro.fl.runtime import masked_accuracy
from repro.kernels.dispatch import UPDATE_IMPLS, resolve_update_impl
from repro.models import cnn

CFG = SMALL_CNN
REPO = Path(__file__).resolve().parents[1]


class TestResolveUpdateImpl:
    def test_concrete_impls_pass_through(self):
        for impl in ("reference", "kernel", "kernel_interpret"):
            assert resolve_update_impl(impl) == impl

    def test_auto_resolves_by_platform(self):
        resolved = resolve_update_impl("auto")
        expected = "kernel" if jax.default_backend() == "tpu" else "reference"
        assert resolved == expected
        assert resolved in UPDATE_IMPLS

    def test_unknown_impl_rejected(self):
        with pytest.raises(ValueError, match="unknown update_impl"):
            resolve_update_impl("cuda")


class TestOverrideUpdateImpl:
    def test_pushes_into_pfedsop_cfg(self):
        m = override_update_impl(PFedSOP(), "kernel_interpret")
        assert m.cfg.update_impl == "kernel_interpret"
        assert hash(m) is not None  # stays frozen/hashable for jit closure

    def test_rejects_methods_without_knob(self):
        with pytest.raises(ValueError, match="no .*update_impl knob"):
            override_update_impl(METHODS["fedavg"](), "kernel_interpret")

    def test_rejects_unknown_impl_before_touching_method(self):
        with pytest.raises(ValueError, match="unknown update_impl"):
            override_update_impl(PFedSOP(), "mosaic")


class TestPersonalizeDispatch:
    def _tree(self, key):
        return {
            "w": jax.random.normal(key, (33, 17)),
            "b": jax.random.normal(jax.random.fold_in(key, 1), (9,)),
        }

    def test_kernel_matches_reference(self):
        tree = self._tree(jax.random.PRNGKey(0))
        di = jax.tree.map(lambda x: x * 0.1, tree)
        dg = jax.tree.map(lambda x: x * -0.05, tree)
        ref_cfg = pf.PFedSOPConfig(eta1=0.02, rho=1.3, lam=0.8,
                                   update_impl="reference")
        ker_cfg = pf.PFedSOPConfig(eta1=0.02, rho=1.3, lam=0.8,
                                   update_impl="kernel_interpret")
        expect, aux_r = pf.personalize(tree, di, dg, ref_cfg)
        got, aux_k = pf.personalize(tree, di, dg, ker_cfg)
        np.testing.assert_allclose(float(aux_k["beta"]), float(aux_r["beta"]),
                                   rtol=1e-5)
        for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(expect)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-6)

    def test_zero_norm_delta_guard(self):
        """Zero deltas (e.g. a client whose local SGD made no progress) hit
        the cosine guard identically in both impls — no NaNs."""
        tree = self._tree(jax.random.PRNGKey(1))
        zeros = jax.tree.map(jnp.zeros_like, tree)
        for impl in ("reference", "kernel_interpret"):
            cfg = pf.PFedSOPConfig(update_impl=impl)
            out, aux = pf.personalize(tree, zeros, zeros, cfg)
            for leaf, orig in zip(jax.tree.leaves(out), jax.tree.leaves(tree)):
                assert np.all(np.isfinite(np.asarray(leaf)))
                np.testing.assert_allclose(np.asarray(leaf), np.asarray(orig),
                                           rtol=1e-6)

    def test_no_pc_ablation_stays_on_reference(self):
        """use_pc=False removes the blend the kernel fuses; both impl
        settings must produce the ablation's reference result."""
        tree = self._tree(jax.random.PRNGKey(2))
        di = jax.tree.map(lambda x: x * 0.3, tree)
        dg = jax.tree.map(lambda x: x * 0.2, tree)
        ref, _ = pf.personalize(tree, di, dg,
                                pf.PFedSOPConfig(use_pc=False, update_impl="reference"))
        ker, _ = pf.personalize(tree, di, dg,
                                pf.PFedSOPConfig(use_pc=False, update_impl="kernel_interpret"))
        for a, b in zip(jax.tree.leaves(ker), jax.tree.leaves(ref)):
            assert np.array_equal(np.asarray(a), np.asarray(b))

    def test_client_round_first_round_branch(self):
        """has_delta=False x global_has_delta=False: personalization is
        masked out, so both impls must yield bit-identical local training."""
        tree = self._tree(jax.random.PRNGKey(3))
        state = pf.init_client_state(tree)
        zeros = jax.tree.map(jnp.zeros_like, tree)
        batches = {"x": jnp.ones((2, 4))}
        loss_fn = lambda p, b: pf.tree_sqnorm(p) * jnp.mean(b["x"])
        outs = {}
        for impl in ("reference", "kernel_interpret"):
            cfg = pf.PFedSOPConfig(update_impl=impl)
            new_state, delta, metrics = pf.client_round(
                loss_fn, state, zeros, jnp.asarray(False), batches, cfg)
            assert not bool(metrics["personalized"])
            outs[impl] = new_state.params
        for a, b in zip(jax.tree.leaves(outs["reference"]),
                        jax.tree.leaves(outs["kernel_interpret"])):
            assert np.array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# End-to-end federation parity, reference vs kernel impl
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def setup():
    images, labels = make_class_conditional_images(400, CFG.n_classes,
                                                   CFG.cnn_image_size, seed=0)
    parts = dirichlet_partition(labels, 8, alpha=0.3, seed=0)
    data = FederatedData.from_partition(images, labels, parts, seed=0)
    params = cnn.init_params(jax.random.PRNGKey(0), CFG)
    loss = lambda p, b: cnn.loss_fn(p, CFG, b)
    acc = masked_accuracy(lambda p, t: cnn.apply(p, CFG, t["images"]))
    return data, params, loss, acc


def _history(setup, backend, update_impl, rounds=3):
    data, params, loss, acc = setup
    run_cfg = FLRunConfig(n_clients=8, participation=0.5, rounds=rounds,
                          batch=8, local_iters=2, seed=1, backend=backend,
                          update_impl=update_impl)
    fed = Federation(PFedSOP(), loss, acc, params, data, run_cfg)
    return fed.run()


def test_federation_impl_parity_vmap(setup):
    """Kernel-impl round histories == reference within fp32 tolerance under
    VmapBackend; rounds=3 covers the has_delta=False first round (masked
    personalization) and the personalized rounds after it."""
    h_ref = _history(setup, "vmap", "reference")
    h_ker = _history(setup, "vmap", "kernel_interpret")
    np.testing.assert_allclose(h_ker["loss"], h_ref["loss"], rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(h_ker["acc"], h_ref["acc"], rtol=1e-5, atol=1e-6)


def test_federation_impl_parity_shard_map_single_device(setup):
    """Same check through ShardMapBackend (degenerate 1-shard mesh): the
    custom-vmap dispatch must fire identically inside shard_map."""
    h_ref = _history(setup, "shard_map", "reference")
    h_ker = _history(setup, "shard_map", "kernel_interpret")
    np.testing.assert_allclose(h_ker["loss"], h_ref["loss"], rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(h_ker["acc"], h_ref["acc"], rtol=1e-5, atol=1e-6)


_MULTIDEV_SCRIPT = textwrap.dedent(
    """
    import jax, numpy as np
    assert len(jax.devices()) == 4, jax.devices()
    from repro.configs.resnet_cifar import SMALL_CNN as CFG
    from repro.core.baselines import PFedSOP
    from repro.data import (FederatedData, dirichlet_partition,
                            make_class_conditional_images)
    from repro.fl import Federation, FLRunConfig
    from repro.fl.runtime import masked_accuracy
    from repro.models import cnn

    images, labels = make_class_conditional_images(400, CFG.n_classes,
                                                   CFG.cnn_image_size, seed=0)
    parts = dirichlet_partition(labels, 8, alpha=0.3, seed=0)
    data = FederatedData.from_partition(images, labels, parts, seed=0)
    params = cnn.init_params(jax.random.PRNGKey(0), CFG)
    loss = lambda p, b: cnn.loss_fn(p, CFG, b)
    acc = masked_accuracy(lambda p, t: cnn.apply(p, CFG, t["images"]))

    hists = {}
    for impl in ["reference", "kernel_interpret"]:
        cfg = FLRunConfig(n_clients=8, participation=0.5, rounds=2, batch=8,
                          local_iters=2, seed=1, backend="shard_map",
                          update_impl=impl)
        fed = Federation(PFedSOP(), loss, acc, params, data, cfg)
        hists[impl] = fed.run()
        assert hists[impl]["engine"]["shards"] == 4, hists[impl]["engine"]
    np.testing.assert_allclose(hists["kernel_interpret"]["loss"],
                               hists["reference"]["loss"], rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(hists["kernel_interpret"]["acc"],
                               hists["reference"]["acc"], rtol=1e-5, atol=1e-6)
    print("MULTIDEV_IMPL_PARITY_OK")
    """
)


def test_federation_impl_parity_shard_map_multi_device():
    """Kernel vs reference impl on a real 4-shard client mesh (forced host
    devices; subprocess because the XLA device count is fixed at jax init)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=4"
    ).strip()
    env["PYTHONPATH"] = str(REPO / "src") + os.pathsep + env.get("PYTHONPATH", "")
    res = subprocess.run(
        [sys.executable, "-c", _MULTIDEV_SCRIPT],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert res.returncode == 0, f"stdout:\n{res.stdout}\nstderr:\n{res.stderr}"
    assert "MULTIDEV_IMPL_PARITY_OK" in res.stdout
