"""Model-level kernel-dispatch tests (DESIGN.md §9).

PR-2 established the dispatch pattern for the pFedSOP round-start update
(tests/test_kernel_dispatch.py); these tests cover its generalization to
the model zoo: the shared ``resolve_impl`` + per-kernel registry, the
``ModelConfig.kernel_impl`` knob threaded through every rmsnorm call site
and the ``attention_fwd`` training/prefill path, and end-to-end parity of
the federated LM example under both impls.
"""
import logging
import os
import re
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels import dispatch
from repro.kernels.dispatch import IMPLS, registered_kernels, resolve_impl
from repro.models import attention as attn_mod
from repro.models import transformer as tf
from repro.models.layers import rmsnorm, rmsnorm_init

REPO = Path(__file__).resolve().parents[1]


class TestResolveImpl:
    def test_all_kernels_resolve_through_one_code_path(self):
        for kernel in ("pfedsop_update", "rmsnorm", "flash_gqa"):
            assert kernel in registered_kernels()
            for impl in ("reference", "kernel", "kernel_interpret"):
                assert resolve_impl(impl, kernel) == impl
            assert resolve_impl("auto", kernel) in ("reference", "kernel")

    def test_unregistered_kernel_rejected(self):
        with pytest.raises(ValueError, match="unregistered kernel"):
            resolve_impl("reference", "flash_mla")

    def test_error_names_the_kernel_knob(self):
        """Each kernel's error message names the config knob its callers
        actually set (update_impl vs kernel_impl)."""
        with pytest.raises(ValueError, match="unknown update_impl"):
            resolve_impl("cuda", "pfedsop_update")
        for kernel in ("rmsnorm", "flash_gqa"):
            with pytest.raises(ValueError, match="unknown kernel_impl"):
                resolve_impl("cuda", kernel)

    def test_auto_resolution_logged_once_per_kernel(self, caplog):
        dispatch._AUTO_LOGGED.discard("rmsnorm")
        with caplog.at_level(logging.INFO, logger="repro.kernels.dispatch"):
            resolve_impl("auto", "rmsnorm")
            resolve_impl("auto", "rmsnorm")
        records = [r for r in caplog.records if "rmsnorm" in r.getMessage()]
        assert len(records) == 1
        msg = records[0].getMessage()
        assert "auto resolved to" in msg and "backend=" in msg

    def test_backend_lookup_is_cached(self):
        dispatch._default_backend.cache_clear()
        assert dispatch._default_backend() == jax.default_backend()
        hits_before = dispatch._default_backend.cache_info().hits
        resolve_impl("auto", "flash_gqa")
        assert dispatch._default_backend.cache_info().hits > hits_before

    def test_model_config_carries_the_knob(self):
        cfg = get_config("gemma3-1b", reduced=True)
        assert cfg.kernel_impl in IMPLS
        assert cfg.replace(kernel_impl="kernel_interpret").kernel_impl == \
            "kernel_interpret"


class TestRMSNormDispatch:
    """The layer-level norm must be parity-exact between impls, including
    the (1 + scale) parametrisation and head_dim < 128 shapes (the qk-norm
    operand layout: (B, S, H, hd))."""

    @pytest.mark.parametrize("shape", [(4, 128), (2, 16, 4, 64), (3, 7, 256),
                                       (1, 8, 1, 96)])
    def test_kernel_interpret_bitwise_vs_reference(self, shape):
        key = jax.random.PRNGKey(shape[-1])
        x = jax.random.normal(key, shape, jnp.float32)
        # non-trivial scale so the (1 + scale) parametrisation is exercised
        p = {"scale": jax.random.normal(jax.random.fold_in(key, 1),
                                        (shape[-1],), jnp.float32) * 0.3}
        ref = rmsnorm(p, x, impl="reference")
        ker = rmsnorm(p, x, impl="kernel_interpret")
        np.testing.assert_array_equal(np.asarray(ker), np.asarray(ref))

    def test_grad_matches_reference(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (8, 128))
        p = rmsnorm_init(128, jnp.float32)
        p = {"scale": p["scale"] + 0.1}

        def loss(p, x, impl):
            return jnp.sum(rmsnorm(p, x, impl=impl) ** 2)

        g_ref = jax.grad(loss, argnums=(0, 1))(p, x, "reference")
        g_ker = jax.grad(loss, argnums=(0, 1))(p, x, "kernel_interpret")
        # dx of sum(norm^2) is near-zero by construction (the norm kills the
        # radial direction), so the comparison needs an absolute floor
        for a, b in zip(jax.tree.leaves(g_ker), jax.tree.leaves(g_ref)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-5)


class TestQKNormAttentionDispatch:
    """attention_fwd with use_qk_norm=True through the kernel path: the
    qk-norm rmsnorm (head_dim < 128, (1 + scale) parametrisation) and the
    flash kernel must together reproduce the blockwise reference."""

    def _cfg(self, window=None, head_dim=64):
        cfg = get_config("gemma3-1b", reduced=True)  # use_qk_norm=True
        assert cfg.use_qk_norm and cfg.head_dim == head_dim < 128
        return cfg

    @pytest.mark.parametrize("window", [None, 16])
    def test_parity(self, window):
        cfg = self._cfg()
        b, s = 2, 64
        key = jax.random.PRNGKey(0)
        x = jax.random.normal(key, (b, s, cfg.d_model), jnp.float32)
        p = attn_mod.attn_init(jax.random.fold_in(key, 1), cfg, jnp.float32)
        # non-zero norm scales so (1 + scale) is exercised through the kernel
        p["q_norm"]["scale"] = p["q_norm"]["scale"] + 0.2
        p["k_norm"]["scale"] = p["k_norm"]["scale"] - 0.1
        pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
        outs = {}
        for impl in ("reference", "kernel_interpret"):
            c = cfg.replace(kernel_impl=impl, attn_q_block=32)
            outs[impl] = np.asarray(
                attn_mod.attention_fwd(p, c, x, pos, window, 10_000.0,
                                       q_block=32))
        np.testing.assert_allclose(outs["kernel_interpret"], outs["reference"],
                                   rtol=2e-5, atol=2e-5)

    def test_qk_norm_actually_fires(self):
        """Sanity: zeroing the qk-norm scales changes the output, so the
        parity above really covers the (1 + scale) path."""
        cfg = self._cfg().replace(kernel_impl="kernel_interpret")
        b, s = 1, 32
        key = jax.random.PRNGKey(2)
        x = jax.random.normal(key, (b, s, cfg.d_model), jnp.float32)
        p = attn_mod.attn_init(jax.random.fold_in(key, 1), cfg, jnp.float32)
        pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
        base = attn_mod.attention_fwd(p, cfg, x, pos, None, 10_000.0, q_block=32)
        p2 = jax.tree.map(lambda v: v, p)
        p2["q_norm"] = {"scale": p["q_norm"]["scale"] + 0.5}
        bumped = attn_mod.attention_fwd(p2, cfg, x, pos, None, 10_000.0, q_block=32)
        assert np.max(np.abs(np.asarray(base) - np.asarray(bumped))) > 1e-4


class TestModelForwardDispatch:
    """Whole-stack parity: forward, loss, and gradients through the scan/
    remat machinery must match between impls on a qk-norm sliding-window
    arch and a plain full-attention arch."""

    @pytest.mark.parametrize("arch", ["gemma3-1b", "granite-3-2b"])
    def test_loss_and_grad_parity(self, arch):
        cfg = get_config(arch, reduced=True)
        b, s = 2, 32
        key = jax.random.PRNGKey(0)
        params = tf.init_params(key, cfg)
        batch = {
            "tokens": jax.random.randint(jax.random.fold_in(key, 1), (b, s),
                                         0, cfg.vocab_size),
            "labels": jax.random.randint(jax.random.fold_in(key, 2), (b, s),
                                         0, cfg.vocab_size),
        }
        losses, grads = {}, {}
        for impl in ("reference", "kernel_interpret"):
            c = cfg.replace(kernel_impl=impl)
            losses[impl], g = jax.value_and_grad(
                lambda p: tf.lm_loss(p, c, batch))(params)
            grads[impl] = g
        np.testing.assert_allclose(float(losses["kernel_interpret"]),
                                   float(losses["reference"]),
                                   rtol=1e-6, atol=1e-7)
        for a, b_ in zip(jax.tree.leaves(grads["kernel_interpret"]),
                         jax.tree.leaves(grads["reference"])):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                       rtol=5e-4, atol=1e-5)

    def test_decode_step_parity(self):
        """Serving decode: the per-step norms dispatch (attention decode
        itself stays on the jnp path) — logits must match across impls."""
        cfg = get_config("gemma3-1b", reduced=True)
        b, cap = 2, 16
        params = tf.init_params(jax.random.PRNGKey(0), cfg)
        tok = jnp.ones((b, 1), jnp.int32)
        logits = {}
        for impl in ("reference", "kernel_interpret"):
            c = cfg.replace(kernel_impl=impl)
            caches = tf.init_caches(c, b, cap)
            out, _ = tf.decode_step(params, c, {"tokens": tok},
                                    jnp.asarray(0, jnp.int32), caches)
            logits[impl] = np.asarray(out)
        np.testing.assert_allclose(logits["kernel_interpret"],
                                   logits["reference"], rtol=1e-5, atol=1e-6)


def test_train_lm_pfedsop_example_impl_parity():
    """The federated LM example must accept --kernel-impl and produce
    identical printed loss histories for reference vs kernel_interpret on
    the same seed (acceptance criterion)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + os.pathsep + env.get("PYTHONPATH", "")
    hists = {}
    for impl in ("reference", "kernel_interpret"):
        res = subprocess.run(
            [sys.executable, str(REPO / "examples" / "train_lm_pfedsop.py"),
             "--arch", "granite-3-2b", "--clients", "2", "--rounds", "2",
             "--local-iters", "1", "--batch", "2", "--seq-len", "32",
             "--kernel-impl", impl],
            capture_output=True, text=True, env=env, timeout=600,
        )
        assert res.returncode == 0, f"stdout:\n{res.stdout}\nstderr:\n{res.stderr}"
        hists[impl] = [float(v) for v in re.findall(r"loss=([0-9.]+)", res.stdout)]
        assert len(hists[impl]) == 2, res.stdout
    # identical histories up to the 6-decimal print resolution (the fp32
    # reduction-order drift is ~1e-6, below what the print resolves)
    np.testing.assert_allclose(hists["kernel_interpret"], hists["reference"],
                               rtol=0, atol=2e-6)
