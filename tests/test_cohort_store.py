"""Cohort-store unit + parity tests (DESIGN.md §12, ISSUE 7).

Units: config validation, LRU eviction order, deferred write-back after
upload, mmap round-trip, cache-hit accounting, checkpoint shard
streaming.  Integration: 3-way backend parity (vmap == shard_map == mesh)
with store=host vs store=device on a forced 8-device mesh, sync AND
async — the §12 bitwise contract — in a subprocess (XLA device count must
be set before jax initialises; the rest of the suite needs the single
real CPU device).
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.fl.cohort_store import (
    DeviceStore,
    HostStore,
    StoreConfig,
    as_store_config,
    make_store,
)

REPO = Path(__file__).resolve().parents[1]

PROTO = {
    "w": np.arange(6, dtype=np.float32).reshape(2, 3),
    "nest": {"b": np.float32(0.5)},
}


def _host(k=8, **kw):
    return make_store(StoreConfig(kind="host", **kw), PROTO, k)


def _rows(store, ids):
    """np view of the at-rest rows for ``ids`` (flushes deferred writes)."""
    return jax.tree.map(lambda a: np.asarray(a[np.asarray(ids)]),
                        store.stacked())


class TestConfig:
    def test_as_store_config_resolution(self):
        assert as_store_config(None).kind == "device"
        assert as_store_config("mmap").kind == "mmap"
        cfg = StoreConfig(kind="host", cache_clients=3)
        assert as_store_config(cfg) is cfg
        with pytest.raises(TypeError):
            as_store_config(42)

    def test_invalid_kind_and_cache_rejected(self):
        with pytest.raises(ValueError, match="store kind"):
            StoreConfig(kind="gpu")
        with pytest.raises(ValueError, match="cache_clients"):
            StoreConfig(cache_clients=-1)
        with pytest.raises(ValueError, match="host/mmap"):
            StoreConfig(kind="device", cache_clients=4)
        with pytest.raises(ValueError, match="ckpt_shard_clients"):
            StoreConfig(ckpt_shard_clients=0)

    def test_make_store_kinds(self):
        assert isinstance(make_store(None, PROTO, 4), DeviceStore)
        assert isinstance(make_store("host", PROTO, 4), HostStore)
        assert not make_store("host", PROTO, 4).mmapped
        assert make_store("mmap", PROTO, 4).mmapped

    def test_host_auto_promotes_to_mmap_past_threshold(self, tmp_path):
        cfg = StoreConfig(kind="host", mmap_threshold_bytes=64,
                          mmap_dir=str(tmp_path))
        assert make_store(cfg, PROTO, 1024).mmapped


class TestGatherScatter:
    @pytest.mark.parametrize("kind", ["device", "host"])
    def test_gather_matches_rows_in_ids_order(self, kind):
        s = make_store(kind, PROTO, 8)
        got = s.gather(np.asarray([5, 1, 1]))
        for name in ["w"]:
            row = np.asarray(got[name])
            assert row.shape == (3, 2, 3)
            np.testing.assert_array_equal(row[0], PROTO["w"])
            np.testing.assert_array_equal(row[1], row[2])

    @pytest.mark.parametrize("kind", ["device", "host"])
    def test_scatter_roundtrips_bitwise(self, kind):
        s = make_store(kind, PROTO, 8)
        ids = np.asarray([2, 6])
        new = {
            "w": jnp.stack([jnp.full((2, 3), 7.25), jnp.full((2, 3), -1.5)]),
            "nest": {"b": jnp.asarray([3.0, 4.0], jnp.float32)},
        }
        s.scatter(ids, new)
        got = _rows(s, ids)
        np.testing.assert_array_equal(got["w"], np.asarray(new["w"]))
        np.testing.assert_array_equal(got["nest"]["b"], [3.0, 4.0])
        # untouched rows keep the broadcast init
        np.testing.assert_array_equal(_rows(s, [0])["w"][0], PROTO["w"])

    def test_host_write_back_is_deferred_until_host_access(self):
        """scatter starts the d2h copy but defers the numpy write until the
        next gather/stacked — the §12 overlap window."""
        s = _host()
        ids = np.asarray([1])
        new = {"w": jnp.ones((1, 2, 3)) * 9.0,
               "nest": {"b": jnp.asarray([8.0], jnp.float32)}}
        s.scatter(ids, new)
        assert len(s._writeback) == 1
        # the raw at-rest array still holds the old value (write deferred)
        np.testing.assert_array_equal(s._data["w"][1], PROTO["w"])
        # any host access flushes
        np.testing.assert_array_equal(_rows(s, [1])["w"][0], 9.0 * np.ones((2, 3)))
        assert not s._writeback

    def test_host_scatter_of_np_rows_writes_through(self):
        """Async deliveries arrive as host numpy rows: direct write, and any
        cached device row for those ids is dropped as stale."""
        s = _host(cache_clients=4)
        s.gather(np.asarray([0, 1]))  # warm the cache
        new = {"w": np.full((1, 2, 3), 5.0, np.float32),
               "nest": {"b": np.asarray([2.0], np.float32)}}
        s.scatter(np.asarray([0]), new)
        assert 0 not in s._lru and 1 in s._lru
        np.testing.assert_array_equal(_rows(s, [0])["w"][0], 5.0)
        # next gather re-fetches the written value through the cache path
        np.testing.assert_array_equal(
            np.asarray(s.gather(np.asarray([0]))["w"][0]), 5.0)


class TestLRUCache:
    def test_eviction_order_is_least_recently_used(self):
        s = _host(cache_clients=2)
        s.gather(np.asarray([0]))
        s.gather(np.asarray([1]))
        s.gather(np.asarray([0]))  # touch 0: now 1 is the LRU entry
        s.gather(np.asarray([2]))  # evicts 1, not 0
        assert list(s._lru) == [0, 2]
        assert s.stats()["cache_evictions"] == 1
        s.gather(np.asarray([1]))  # miss: evicts 0 (front of [0, 2])
        assert list(s._lru) == [2, 1]

    def test_hit_accounting_and_h2d_savings(self):
        s = _host(cache_clients=4)
        s.gather(np.asarray([0, 1, 2, 3]))
        st = s.stats()
        assert (st["cache_hits"], st["cache_misses"]) == (0, 4)
        moved = st["h2d_bytes"]
        s.gather(np.asarray([3, 0]))  # pure hits: no new h2d traffic
        st = s.stats()
        assert (st["cache_hits"], st["cache_misses"]) == (2, 4)
        assert st["h2d_bytes"] == moved

    def test_cohort_larger_than_cache_is_still_correct(self):
        """K' > cache_clients: every id resolves even though insertion
        evicts earlier rows of the same cohort (regression test)."""
        s = _host(k=8, cache_clients=2)
        ids = np.asarray([0, 1, 2, 3, 0])
        got = s.gather(ids)
        assert np.asarray(got["w"]).shape == (5, 2, 3)
        s2 = _host(k=8)
        np.testing.assert_array_equal(np.asarray(got["w"]),
                                      np.asarray(s2.gather(ids)["w"]))

    def test_device_scatter_write_allocates_cache(self):
        s = _host(cache_clients=2)
        new = {"w": jnp.zeros((1, 2, 3)), "nest": {"b": jnp.asarray([1.0])}}
        s.scatter(np.asarray([5]), new)
        assert 5 in s._lru
        s.gather(np.asarray([5]))
        assert s.stats()["cache_hits"] == 1

    def test_sharded_gather_bypasses_cache(self):
        """A non-None shardings tree takes the bypass path: no cache fills."""
        dev = jax.devices()[0]
        shardings = jax.tree.map(
            lambda _: jax.sharding.SingleDeviceSharding(dev), PROTO)
        s = _host(cache_clients=4)
        s.gather(np.asarray([0, 1]), shardings)
        assert not s._lru
        assert s.stats()["cache_misses"] == 0


class TestMmap:
    def test_mmap_roundtrip_on_disk(self, tmp_path):
        cfg = StoreConfig(kind="mmap", mmap_dir=str(tmp_path))
        s = make_store(cfg, PROTO, 6)
        files = sorted(p.name for p in tmp_path.glob("*.mmap"))
        assert files, "mmap store must back its leaves with files"
        new = {"w": jnp.full((2, 2, 3), 4.5),
               "nest": {"b": jnp.asarray([1.0, 2.0], jnp.float32)}}
        s.scatter(np.asarray([0, 5]), new)
        got = s.gather(np.asarray([5, 0, 3]))
        np.testing.assert_array_equal(np.asarray(got["nest"]["b"]),
                                      [2.0, 1.0, 0.5])
        # the bytes really live in the backing file
        s.stacked()  # flush
        disk = np.memmap(tmp_path / "w.mmap", dtype=np.float32,
                         mode="r", shape=(6, 2, 3))
        np.testing.assert_array_equal(disk[0], 4.5 * np.ones((2, 3)))

    def test_shard_save_load_roundtrip(self, tmp_path):
        s = _host(k=10, ckpt_shard_clients=3)  # 4 shards: 3+3+3+1
        rng = np.random.RandomState(0)
        full = {"w": rng.randn(10, 2, 3).astype(np.float32),
                "nest": {"b": rng.randn(10).astype(np.float32)}}
        s.load_stacked(full)
        s.save_shards(tmp_path)
        assert len(list(tmp_path.glob("store_*.npz"))) == 4
        # a reader with DIFFERENT shard granularity restores exactly
        r = _host(k=10, ckpt_shard_clients=7)
        r.load_shards(tmp_path)
        for a, b in zip(jax.tree.leaves(r.stacked()), jax.tree.leaves(full)):
            np.testing.assert_array_equal(np.asarray(a), b)

    def test_shard_load_rejects_wrong_k_and_leaves(self, tmp_path):
        s = _host(k=4)
        s.save_shards(tmp_path)
        with pytest.raises(ValueError, match="clients"):
            _host(k=5).load_shards(tmp_path)
        other = make_store("host", {"z": np.zeros(3, np.float32)}, 4)
        with pytest.raises(ValueError, match="leaves"):
            other.load_shards(tmp_path)


class TestOffload:
    def test_host_store_offload_always_host(self):
        s = _host()
        out = s.offload({"x": jnp.ones(3)})
        assert isinstance(out["x"], np.ndarray)

    def test_device_store_offload_respects_force(self):
        s = make_store(None, PROTO, 4)
        dev = s.offload({"x": jnp.ones(3)})
        assert isinstance(dev["x"], jax.Array)
        host = s.offload({"x": jnp.ones(3)}, force_host=True)
        assert isinstance(host["x"], np.ndarray)


_PARITY_SCRIPT = textwrap.dedent(
    """
    import jax, numpy as np
    assert len(jax.devices()) == 8, jax.devices()
    from repro.configs.resnet_cifar import SMALL_CNN as CFG
    from repro.core.baselines import METHODS
    from repro.data import (FederatedData, dirichlet_partition,
                            make_class_conditional_images)
    from repro.fl import (AsyncConfig, AsyncFederation, AvailabilityConfig,
                          Federation, FLRunConfig)
    from repro.fl.runtime import masked_accuracy
    from repro.models import cnn

    images, labels = make_class_conditional_images(400, CFG.n_classes,
                                                   CFG.cnn_image_size, seed=0)
    parts = dirichlet_partition(labels, 8, alpha=0.3, seed=0)
    data = FederatedData.from_partition(images, labels, parts, seed=0)
    params = cnn.init_params(jax.random.PRNGKey(0), CFG)
    loss = lambda p, b: cnn.loss_fn(p, CFG, b)
    acc = masked_accuracy(lambda p, t: cnn.apply(p, CFG, t["images"]))

    def run(backend, mesh, store, mode):
        cfg = FLRunConfig(n_clients=8, participation=0.5, rounds=2, batch=8,
                          local_iters=2, seed=1, backend=backend, mesh=mesh,
                          store=store)
        if mode == "async":
            fed = AsyncFederation(METHODS["pfedsop"](), loss, acc, params,
                                  data, cfg,
                                  AsyncConfig(buffer_size=4, concurrency=4,
                                              availability=AvailabilityConfig()))
        else:
            fed = Federation(METHODS["pfedsop"](), loss, acc, params, data, cfg)
        h = fed.run()
        states = jax.tree.leaves(jax.tree.map(np.asarray, fed.client_states))
        return h, states

    ref = None
    for backend, mesh in [("vmap", ""), ("shard_map", ""),
                          ("mesh", "pods:2x2x2")]:
        for store in ["device", "host"]:
            mode_grid = ["sync", "async"] if store == "host" else ["sync"]
            for mode in mode_grid:
                h, states = run(backend, mesh, store, mode)
                if ref is None:
                    ref = (h, states)
                else:
                    assert h["loss"] == ref[0]["loss"], (backend, store, mode)
                    assert h["acc"] == ref[0]["acc"], (backend, store, mode)
                    assert all(np.array_equal(a, b)
                               for a, b in zip(ref[1], states)), (
                        backend, store, mode)
    print("COHORT_STORE_PARITY_OK")
    """
)


def test_three_way_backend_parity_host_vs_device_8dev():
    """vmap == shard_map == mesh, store=host vs store=device, sync + async:
    loss/acc histories AND final client states bitwise identical on a
    forced 8-device mesh (ISSUE 7 acceptance)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=8"
    ).strip()
    env["PYTHONPATH"] = str(REPO / "src") + os.pathsep + env.get("PYTHONPATH", "")
    res = subprocess.run(
        [sys.executable, "-c", _PARITY_SCRIPT],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert res.returncode == 0, f"stdout:\n{res.stdout}\nstderr:\n{res.stderr}"
    assert "COHORT_STORE_PARITY_OK" in res.stdout
