"""Checkpoint/resume tests for both federation drivers (DESIGN.md §10).

The contract: run-to-2R produces the same loss/acc history as
run-to-R -> save -> fresh driver -> restore -> run-to-2R, bitwise, for
the synchronous AND the asynchronous driver (the async case checkpoints
mid-simulation: scheduler heap, in-flight results and a partially filled
aggregation buffer all round-trip).  Plus units for the RandomState
snapshot helpers and the participated-mask fix to mean_best_acc.
"""
from dataclasses import replace
from pathlib import Path

import jax
import numpy as np
import pytest
from hyp_compat import given, hst, settings  # optional-hypothesis shim

from repro.configs.resnet_cifar import SMALL_CNN
from repro.core.baselines import METHODS
from repro.data import FederatedData, dirichlet_partition, make_class_conditional_images
from repro.fl import (
    AsyncConfig,
    AsyncFederation,
    AvailabilityConfig,
    Federation,
    FLRunConfig,
)
from repro.fl.runtime import masked_accuracy
from repro.models import cnn
from repro.utils.checkpoint import (
    latest_step,
    read_manifest,
    restore_rng_state,
    rng_state_tree,
)

CFG = SMALL_CNN

HETERO = AvailabilityConfig(speed="lognormal", sigma=1.0,
                            availability=0.3, mean_on=4.0)


@pytest.fixture(scope="module")
def setup():
    images, labels = make_class_conditional_images(800, CFG.n_classes,
                                                   CFG.cnn_image_size, seed=0)
    parts = dirichlet_partition(labels, 8, alpha=0.3, seed=0)
    data = FederatedData.from_partition(images, labels, parts, seed=0)
    params = cnn.init_params(jax.random.PRNGKey(0), CFG)
    loss = lambda p, b: cnn.loss_fn(p, CFG, b)
    acc = masked_accuracy(lambda p, t: cnn.apply(p, CFG, t["images"]))
    return data, params, loss, acc


def _cfg(rounds=4, **kw):
    return FLRunConfig(n_clients=8, participation=0.5, rounds=rounds,
                       batch=8, local_iters=2, seed=1, **kw)


def test_rng_state_roundtrip():
    rng = np.random.RandomState(123)
    rng.normal(size=7)  # leave a cached gaussian in the state
    tree = rng_state_tree(rng)
    rng2 = np.random.RandomState(0)
    restore_rng_state(rng2, tree)
    np.testing.assert_array_equal(rng.normal(size=16), rng2.normal(size=16))
    np.testing.assert_array_equal(rng.choice(100, 10, replace=False),
                                  rng2.choice(100, 10, replace=False))


def test_sync_resume_matches_uninterrupted(setup, tmp_path):
    """run-to-2R == run-to-R -> save -> restore -> run-to-2R (sync)."""
    data, params, loss, acc = setup
    method = METHODS["pfedsop"]
    full = Federation(method(), loss, acc, params, data, _cfg()).run()

    cfg = _cfg(ckpt_every=2, ckpt_dir=str(tmp_path / "sync"))
    Federation(method(), loss, acc, params, data, cfg).run()
    assert latest_step(cfg.ckpt_dir) == 4  # saved at rounds 2 and 4
    assert read_manifest(cfg.ckpt_dir, 2)["extra"]["driver"] == "sync"

    fed = Federation(method(), loss, acc, params, data, cfg)
    assert fed.restore(step=2) == 2
    resumed = fed.run()
    assert resumed["loss"] == full["loss"]
    assert resumed["acc"] == full["acc"]
    assert resumed["sim_time"] == full["sim_time"]
    assert resumed["mean_best_acc"] == full["mean_best_acc"]


@pytest.mark.parametrize("method", ["pfedsop", "fedavg"])
def test_async_resume_matches_uninterrupted(setup, tmp_path, method):
    """Async resume, heterogeneous config: the checkpoint cut lands with
    in-flight work and (typically) a partially filled buffer, and the
    resumed event loop still reproduces the uninterrupted run bitwise."""
    data, params, loss, acc = setup
    acfg = AsyncConfig(buffer_size=2, concurrency=4, availability=HETERO)
    make = lambda cfg: AsyncFederation(METHODS[method](), loss, acc, params,
                                       data, cfg, acfg)
    full = make(_cfg()).run()

    cfg = _cfg(ckpt_every=2, ckpt_dir=str(tmp_path / f"async_{method}"))
    make(cfg).run()
    assert read_manifest(cfg.ckpt_dir, 2)["extra"]["driver"] == "async"

    fed = make(cfg)
    assert fed.restore(step=2) == 2
    resumed = fed.run()
    assert resumed["loss"] == full["loss"]
    assert resumed["acc"] == full["acc"]
    assert resumed["sim_time"] == full["sim_time"]
    assert resumed["staleness"] == full["staleness"]
    assert resumed["mean_best_acc"] == full["mean_best_acc"]


def test_async_resume_mid_cohort_flush(setup, tmp_path):
    """Checkpoint cut by a flush in the MIDDLE of a delivered micro-cohort.

    Uniform speeds make the whole K'=4 cohort complete simultaneously;
    buffer_size=3 does not divide it, so every flush leaves part of the
    just-delivered cohort sitting in the buffer.  With ckpt_every=1 a
    checkpoint lands on each of those flushes — the saved state must
    include the not-yet-aggregated tail of the cohort, or the resumed run
    diverges (regression: _deliver once flushed while appending)."""
    data, params, loss, acc = setup
    acfg = AsyncConfig(buffer_size=3)  # degenerate speeds, K' = 4
    make = lambda cfg: AsyncFederation(METHODS["pfedsop"](), loss, acc, params,
                                       data, cfg, acfg)
    full = make(_cfg(rounds=5)).run()

    cfg = _cfg(rounds=5, ckpt_every=1, ckpt_dir=str(tmp_path / "midflush"))
    make(cfg).run()
    mani = read_manifest(cfg.ckpt_dir, 2)["extra"]
    assert mani["n_buffer"] > 0  # the cut really does land mid-cohort

    fed = make(cfg)
    assert fed.restore(step=2) == 2
    resumed = fed.run()
    assert resumed["loss"] == full["loss"]
    assert resumed["acc"] == full["acc"]
    assert resumed["staleness"] == full["staleness"]


def test_async_resume_from_intermediate_flush(setup, tmp_path):
    """Checkpoint written by a NON-final flush of a multi-flush delivery:
    the restored buffer still holds >= buffer_size uploads.  The resumed
    run must drain those flushes before dispatching the next micro-cohort,
    exactly as the uninterrupted run did (regression: _step once
    dispatched first, so the next cohort trained against an older
    broadcast and recorded lower versions — wrong staleness, diverging
    loss history)."""
    data, params, loss, acc = setup
    acfg = AsyncConfig(buffer_size=1, concurrency=4)  # uniform speeds, K'=4
    make = lambda cfg: AsyncFederation(METHODS["pfedsop"](), loss, acc, params,
                                       data, cfg, acfg)
    full = make(_cfg(rounds=8)).run()

    cfg = _cfg(rounds=8, ckpt_every=1, ckpt_dir=str(tmp_path / "interflush"))
    make(cfg).run()
    # version 1 = first flush of a simultaneously-delivered 4-cohort: the
    # saved buffer still holds the 3 remaining uploads (>= buffer_size)
    mani = read_manifest(cfg.ckpt_dir, 1)["extra"]
    assert mani["n_buffer"] >= acfg.buffer_size

    fed = make(cfg)
    assert fed.restore(step=1) == 1
    resumed = fed.run()
    assert resumed["loss"] == full["loss"]
    assert resumed["acc"] == full["acc"]
    assert resumed["staleness"] == full["staleness"]
    assert resumed["sim_time"] == full["sim_time"]
    assert resumed["mean_best_acc"] == full["mean_best_acc"]


def test_sync_restore_rejects_async_checkpoint(setup, tmp_path):
    data, params, loss, acc = setup
    cfg = _cfg(rounds=2, ckpt_every=2, ckpt_dir=str(tmp_path / "mix2"))
    AsyncFederation(METHODS["pfedsop"](), loss, acc, params, data, cfg,
                    AsyncConfig()).run()
    fed = Federation(METHODS["pfedsop"](), loss, acc, params, data, cfg)
    with pytest.raises(ValueError, match="driver"):
        fed.restore()


def test_async_restore_rejects_sync_checkpoint(setup, tmp_path):
    data, params, loss, acc = setup
    cfg = _cfg(rounds=2, ckpt_every=2, ckpt_dir=str(tmp_path / "mix"))
    Federation(METHODS["pfedsop"](), loss, acc, params, data, cfg).run()
    fed = AsyncFederation(METHODS["pfedsop"](), loss, acc, params, data, cfg)
    with pytest.raises(ValueError, match="driver"):
        fed.restore()


def test_sync_restore_rejects_config_mismatch(setup, tmp_path):
    """Resuming under a different run config (here: participation) would
    replay the restored RNG stream over different cohort shapes and
    silently diverge; the stamped run fingerprint rejects it."""
    data, params, loss, acc = setup
    cfg = _cfg(rounds=2, ckpt_every=2, ckpt_dir=str(tmp_path / "syncmix"))
    Federation(METHODS["pfedsop"](), loss, acc, params, data, cfg).run()
    bad = replace(cfg, participation=0.25)
    fed = Federation(METHODS["pfedsop"](), loss, acc, params, data, bad)
    with pytest.raises(ValueError, match="run config"):
        fed.restore()


def test_async_restore_rejects_config_mismatch(setup, tmp_path):
    """Resuming with a different resolved AsyncConfig would silently
    break the bitwise-continuation contract (different flush cadence,
    different staleness): the stamped manifest fingerprint rejects it."""
    data, params, loss, acc = setup
    cfg = _cfg(rounds=2, ckpt_every=2, ckpt_dir=str(tmp_path / "cfgmix"))
    AsyncFederation(METHODS["pfedsop"](), loss, acc, params, data, cfg,
                    AsyncConfig(buffer_size=2)).run()
    fed = AsyncFederation(METHODS["pfedsop"](), loss, acc, params, data, cfg,
                          AsyncConfig(buffer_size=4))
    with pytest.raises(ValueError, match="async config"):
        fed.restore()
    # identical resolved config (0 resolves to K' = 4 = the saved
    # concurrency) restores fine
    ok = AsyncFederation(METHODS["pfedsop"](), loss, acc, params, data, cfg,
                         AsyncConfig(buffer_size=2, concurrency=0))
    assert ok.restore() == 2


# ---------------------------------------------------------------------------
# Round-trip fuzz (ISSUE 7): random (backend, mode, ckpt_every, interrupt,
# store) draws generalize the hand-picked cases above.  The @given variant
# runs wherever hypothesis is installed (CI); the grid companion pins three
# seeds so a bare interpreter still exercises the property.
# ---------------------------------------------------------------------------


def _fuzz_roundtrip(setup, tmp_path, seed):
    data, params, loss, acc = setup
    rng = np.random.RandomState(seed)
    backend = ["vmap", "shard_map"][rng.randint(2)]
    mode = ["sync", "async"][rng.randint(2)]
    store = ["device", "host"][rng.randint(2)]
    rounds = int(rng.randint(3, 6))
    ckpt_every = int(rng.randint(1, 3))
    # interrupt at a step a checkpoint actually landed on
    interrupt = ckpt_every * int(rng.randint(1, rounds // ckpt_every + 1))
    tag = f"fuzz_{seed}_{backend}_{mode}_{store}"

    def make(cfg):
        if mode == "async":
            return AsyncFederation(
                METHODS["pfedsop"](), loss, acc, params, data, cfg,
                AsyncConfig(buffer_size=2, concurrency=4, availability=HETERO))
        return Federation(METHODS["pfedsop"](), loss, acc, params, data, cfg)

    base = _cfg(rounds=rounds, backend=backend, store=store)
    ref = make(base)
    full = ref.run()
    cfg = replace(base, ckpt_every=ckpt_every,
                  ckpt_dir=str(tmp_path / tag))
    make(cfg).run()
    fed = make(cfg)
    assert fed.restore(step=interrupt) == interrupt, (seed, tag)
    resumed = fed.run()
    for key in ["loss", "acc", "sim_time", "mean_best_acc"]:
        assert resumed[key] == full[key], (seed, tag, key)
    # bitwise final client states, streamed through the store both ways
    final = jax.tree.leaves(jax.tree.map(np.asarray, fed.client_states))
    want = jax.tree.leaves(jax.tree.map(np.asarray, ref.client_states))
    assert all(np.array_equal(a, b) for a, b in zip(final, want)), (seed, tag)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_resume_roundtrip_fuzz_grid(setup, tmp_path, seed):
    _fuzz_roundtrip(setup, tmp_path, seed)


@given(seed=hst.integers(0, 10_000))
@settings(max_examples=5, deadline=None)
def test_resume_roundtrip_fuzzed(setup, tmp_path_factory, seed):
    _fuzz_roundtrip(setup, tmp_path_factory.mktemp(f"fz{seed}"), seed)


def test_async_mid_drain_checkpoint_with_host_store(setup, tmp_path):
    """Checkpoint cut mid-drain with the streamed store (DESIGN.md §12):
    the buffer holds pending uploads (host numpy rows routed through the
    store's write-through scatter path) AND the store's shard files must
    round-trip beside the driver arrays, bitwise."""
    data, params, loss, acc = setup
    acfg = AsyncConfig(buffer_size=3)  # K'=4: every flush leaves a tail
    make = lambda cfg: AsyncFederation(METHODS["pfedsop"](), loss, acc,
                                       params, data, cfg, acfg)
    base = _cfg(rounds=5, store="host")
    full = make(base).run()

    cfg = replace(base, ckpt_every=1, ckpt_dir=str(tmp_path / "middrain"))
    make(cfg).run()
    mani = read_manifest(cfg.ckpt_dir, 2)["extra"]
    assert mani["n_buffer"] > 0  # the cut really lands mid-drain
    # the store streamed its shards into the step directory
    step_dir = Path(cfg.ckpt_dir) / "step_00000002"
    assert (step_dir / "store_manifest.json").exists()
    assert list(step_dir.glob("store_*.npz"))

    fed = make(cfg)
    assert fed.restore(step=2) == 2
    resumed = fed.run()
    assert resumed["loss"] == full["loss"]
    assert resumed["acc"] == full["acc"]
    assert resumed["staleness"] == full["staleness"]


def test_restore_rejects_store_kind_mismatch(setup, tmp_path):
    """The run fingerprint gains the store config: resuming a host-store
    checkpoint with a device store would reload shard files into a
    different at-rest layout than the one stamped at save time."""
    data, params, loss, acc = setup
    cfg = _cfg(rounds=2, ckpt_every=2, ckpt_dir=str(tmp_path / "storemix"),
               store="host")
    Federation(METHODS["pfedsop"](), loss, acc, params, data, cfg).run()
    bad = replace(cfg, store="device")
    fed = Federation(METHODS["pfedsop"](), loss, acc, params, data, bad)
    with pytest.raises(ValueError, match="run config"):
        fed.restore()


def test_mean_best_acc_counts_zero_acc_participants(setup):
    """The participated mask replaces the old ``best_acc > 0`` proxy: a
    participating client whose best accuracy is legitimately 0.0 must
    drag the mean down, not silently vanish from it."""
    data, params, loss, acc = setup
    fed = Federation(METHODS["pfedsop"](), loss, acc, params, data,
                     _cfg(rounds=2))
    hist = fed.run()
    assert hist["mean_best_acc"] == float(
        np.mean(fed.best_acc[fed.participated]))
    # the regression scenario: participants pinned to best acc 0.0 must
    # yield mean 0.0 (the old ``best_acc > 0`` proxy dropped them all and
    # np.mean of the empty selection returned nan)
    fed.best_acc[fed.participated] = 0.0
    with_zero = (float(np.mean(fed.best_acc[fed.participated]))
                 if fed.participated.any() else 0.0)
    assert with_zero == 0.0


def test_participated_tracks_rounds_seen(setup):
    data, params, loss, acc = setup
    fed = Federation(METHODS["pfedsop"](), loss, acc, params, data,
                     _cfg(rounds=3))
    fed.run()
    seen = np.asarray(fed.client_states.rounds_seen)
    np.testing.assert_array_equal(fed.participated, seen > 0)
