"""Multi-pod mesh engine parity tests (DESIGN.md §11).

The acceptance anchor of the mesh refactor: on a forced 8-device
`(pod=2, data=2, model=2)` mesh, bitwise parity must hold in all three
degenerate directions —

  multi-pod ``MeshBackend`` == 1-D ``ShardMapBackend`` == ``VmapBackend``
  loss/accuracy histories (sync), and always-on/uniform/buffer=K'
  multi-pod async == sync history

— with the model-sharded batched ``pfedsop_update`` kernel active on the
hot path (``kernel_interpret`` so the kernel body actually runs on CPU).
Subprocess: the XLA device count must be forced before jax initialises,
and the rest of the suite needs the single real CPU device (cf.
tests/test_engine.py).
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]

_MULTIPOD_SCRIPT = textwrap.dedent(
    """
    import jax, numpy as np, jax.numpy as jnp
    assert len(jax.devices()) == 8, jax.devices()
    from jax.sharding import PartitionSpec as P
    try:
        from jax import shard_map
    except ImportError:
        from jax.experimental.shard_map import shard_map

    from repro.configs.resnet_cifar import SMALL_CNN as CFG
    from repro.core.baselines import METHODS
    from repro.data import (FederatedData, dirichlet_partition,
                            make_class_conditional_images)
    from repro.fl import AsyncFederation, Federation, FLRunConfig
    from repro.fl.runtime import masked_accuracy
    from repro.kernels.pfedsop_update.ops import (
        pfedsop_update_batched, pfedsop_update_batched_sharded)
    from repro.launch.mesh import MeshSpec, resolve_mesh
    from repro.models import cnn

    # -- 1. model-sharded kernel op: bitwise vs the unsharded kernel ------
    mesh = resolve_mesh(MeshSpec.multi_pod(2, 2, 2))
    k = jax.random.PRNGKey(0)
    for n in [130, 4096 + 7]:  # sub-tile and non-tile-multiple N
        x, di = (jax.random.normal(jax.random.fold_in(k, i), (4, n))
                 for i in (1, 2))
        dg = jax.random.normal(jax.random.fold_in(k, 3), (n,))
        ref, beta_ref = pfedsop_update_batched(x, di, dg, interpret=True)
        out, beta = shard_map(
            lambda x, di, dg: pfedsop_update_batched_sharded(
                x, di, dg, "model", 2, interpret=True),
            mesh=mesh, in_specs=(P(), P(), P()), out_specs=P(),
            check_rep=False)(x, di, dg)
        assert np.array_equal(np.asarray(ref), np.asarray(out)), n
        assert np.array_equal(np.asarray(beta_ref), np.asarray(beta)), n
    print("KERNEL_SHARDED_BITWISE_OK")

    # -- shared federation fixtures ---------------------------------------
    images, labels = make_class_conditional_images(600, CFG.n_classes,
                                                   CFG.cnn_image_size, seed=0)
    parts = dirichlet_partition(labels, 8, alpha=0.3, seed=0)
    data = FederatedData.from_partition(images, labels, parts, seed=0)
    params = cnn.init_params(jax.random.PRNGKey(0), CFG)
    loss = lambda p, b: cnn.loss_fn(p, CFG, b)
    acc = masked_accuracy(lambda p, t: cnn.apply(p, CFG, t["images"]))

    def run_cfg(backend, mesh="", rounds=2):
        # K' = 4: divisible by the 2 pods AND by 4/8-way client splits
        return FLRunConfig(n_clients=8, participation=0.5, rounds=rounds,
                           batch=8, local_iters=2, seed=1, backend=backend,
                           mesh=mesh, update_impl="kernel_interpret")

    # -- 2. sync three-way bitwise parity, model-sharded kernel active ----
    hists = {}
    for backend, mesh_spec in [("vmap", ""), ("shard_map", ""),
                               ("mesh", "pods:2x2x2")]:
        fed = Federation(METHODS["pfedsop"](), loss, acc, params, data,
                         run_cfg(backend, mesh_spec))
        hists[backend] = fed.run()
    eng = hists["mesh"]["engine"]
    assert eng["mesh"].startswith("pod=2,data=2,model=2"), eng
    assert eng["shards"] == 2 and eng["model_shards"] == 2, eng
    assert hists["shard_map"]["engine"]["shards"] == 4
    for b in ["shard_map", "mesh"]:
        assert hists["vmap"]["loss"] == hists[b]["loss"], (b, hists)
        assert hists["vmap"]["acc"] == hists[b]["acc"], (b, hists)
    print("SYNC_THREEWAY_BITWISE_OK")

    # -- 3. degenerate multi-pod async == sync (per-pod streams) ----------
    h_sync = Federation(METHODS["pfedsop"](), loss, acc, params, data,
                        run_cfg("vmap", rounds=3)).run()
    fed = AsyncFederation(METHODS["pfedsop"](), loss, acc, params, data,
                          run_cfg("mesh", "pods:2x2x2", rounds=3))
    assert fed.n_pods == 2, fed.n_pods
    h_async = fed.run()
    assert h_sync["loss"] == h_async["loss"]
    assert h_sync["acc"] == h_async["acc"]
    assert h_sync["sim_time"] == h_async["sim_time"]
    assert h_async["staleness"] == [0.0] * 3
    # per-pod delivery streams: the K'/2-sized pod cohorts actually ran
    assert 2 in h_async["engine"]["cohort_sizes"], h_async["engine"]
    print("ASYNC_MULTIPOD_DEGENERATE_OK")

    # -- 4. non-divisor micro-cohorts fall back (async lenient mode) -----
    from repro.fl import AsyncConfig
    from repro.fl.availability import AvailabilityConfig
    acfg = AsyncConfig(buffer_size=1, concurrency=3,
                       availability=AvailabilityConfig(speed="lognormal",
                                                       sigma=1.0))
    h = AsyncFederation(METHODS["pfedsop"](), loss, acc, params, data,
                        run_cfg("mesh", "pods:2x2x2", rounds=3), acfg).run()
    assert len(h["loss"]) == 3
    assert any(c % 2 for c in h["engine"]["cohort_sizes"]), h["engine"]
    print("ASYNC_FALLBACK_OK")

    # -- 5. mid-drain checkpoint resume: with buffer_size = K'/pods, each
    # pod-0 delivery flushes (and checkpoints) while pod 1's same-time
    # completions are still in the heap; resuming from that checkpoint
    # must deliver pod 1 BEFORE the next dispatch draw, or the RNG stream
    # (and history) diverges from the uninterrupted run
    import dataclasses, tempfile
    ckdir = tempfile.mkdtemp()
    cfg5 = dataclasses.replace(run_cfg("mesh", "pods:2x2x2", rounds=4),
                               ckpt_every=1, ckpt_dir=ckdir)
    h_full = AsyncFederation(METHODS["pfedsop"](), loss, acc, params, data,
                             cfg5, AsyncConfig(buffer_size=2)).run()
    fed_r = AsyncFederation(METHODS["pfedsop"](), loss, acc, params, data,
                            cfg5, AsyncConfig(buffer_size=2))
    assert fed_r.restore(ckdir, step=1) == 1  # written mid-drain
    h_res = fed_r.run()
    assert h_full["loss"] == h_res["loss"]
    assert h_full["acc"] == h_res["acc"]
    assert h_full["sim_time"] == h_res["sim_time"]
    print("ASYNC_MULTIPOD_RESUME_OK")
    """
)


def test_multipod_parity_forced_8_devices():
    """Three-way sync bitwise parity + degenerate multi-pod async == sync
    + model-sharded kernel bitwise + lenient micro-cohort fallback, all on
    a forced 8-device (2,2,2) mesh (one subprocess to amortize compiles).
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=8"
    ).strip()
    env["PYTHONPATH"] = str(REPO / "src") + os.pathsep + env.get("PYTHONPATH", "")
    res = subprocess.run(
        [sys.executable, "-c", _MULTIPOD_SCRIPT],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert res.returncode == 0, f"stdout:\n{res.stdout}\nstderr:\n{res.stderr}"
    for marker in ["KERNEL_SHARDED_BITWISE_OK", "SYNC_THREEWAY_BITWISE_OK",
                   "ASYNC_MULTIPOD_DEGENERATE_OK", "ASYNC_FALLBACK_OK",
                   "ASYNC_MULTIPOD_RESUME_OK"]:
        assert marker in res.stdout, res.stdout
