"""Mesh-layer and shard-resolution unit tests (DESIGN.md §11).

Single real CPU device: everything here validates the host-side spec /
resolution / error-message layer (plus the trace-driven availability
model).  The forced-8-device end-to-end parity lives in
tests/test_multipod.py.
"""
import dataclasses
import json

import numpy as np
import pytest

from repro.fl import (
    AvailabilityConfig,
    TraceAvailability,
    TraceAvailabilityConfig,
    make_availability,
    make_engine,
    resolve_client_split,
)
from repro.launch.mesh import (
    MeshSpec,
    is_auto_clients,
    make_production_mesh,
    parse_mesh,
    resolve_mesh,
)
from repro.launch import sharding as sh
from jax.sharding import PartitionSpec as P


class TestMeshSpec:
    def test_roles_and_sizes(self):
        s = MeshSpec.multi_pod(2, 4, 8)
        assert s.axes == ("pod", "data", "model")
        assert (s.client_size, s.data_size, s.model_size) == (2, 4, 8)
        assert s.n_devices == 64
        assert MeshSpec.clients(4).model_size == 1  # absent role -> 1

    def test_signature_stable_and_role_annotated(self):
        assert MeshSpec.multi_pod(2, 2, 2).signature() == (
            "pod=2,data=2,model=2[client:pod,data:data,model:model]")
        assert MeshSpec.clients(4).signature() == "clients=4[client:clients]"

    def test_validation(self):
        with pytest.raises(ValueError, match="length mismatch"):
            MeshSpec((2, 2), ("a",))
        with pytest.raises(ValueError, match="duplicate"):
            MeshSpec((2, 2), ("a", "a"))
        with pytest.raises(ValueError, match="not a mesh axis"):
            MeshSpec((2,), ("a",), client_axis="b")
        with pytest.raises(ValueError, match="non-positive"):
            MeshSpec((0,), ("a",))


class TestParseMesh:
    def test_grammar(self):
        assert parse_mesh("pods:2x16x16") == MeshSpec.multi_pod(2, 16, 16)
        assert parse_mesh("pod:16x16") == MeshSpec.single_pod(16, 16)
        assert parse_mesh("host") == MeshSpec.host()
        assert parse_mesh("clients:4") == MeshSpec.clients(4)
        assert is_auto_clients(parse_mesh("clients"))
        assert is_auto_clients(parse_mesh("clients:0"))

    @pytest.mark.parametrize("bad", ["", "pods", "pods:2x2", "pod:2x2x2",
                                     "clients:-1", "torus:2x2", "host:1"])
    def test_rejects_with_grammar_in_message(self, bad):
        with pytest.raises(ValueError, match="mesh spec"):
            parse_mesh(bad)


class TestResolveMesh:
    # NOTE: the in-process tier-1 suite may see 512 forced host devices
    # (collection imports repro.launch.dryrun, which sets XLA_FLAGS), so
    # shortfall assertions use specs larger than any simulated box.

    def test_device_shortfall_message_names_flag_and_count(self):
        """The error must say how many devices the spec needs and how to
        force them (the actionable part of the §11 contract)."""
        with pytest.raises(RuntimeError) as e:
            resolve_mesh(MeshSpec.multi_pod(2, 64, 64))
        msg = str(e.value)
        assert "8192 devices" in msg
        assert "xla_force_host_platform_device_count=8192" in msg

    def test_production_mesh_shape_parameterized(self):
        """make_production_mesh is no longer hard-coded to (2, 16, 16):
        an explicit shape routes through resolve_mesh (and still
        validates the device count)."""
        with pytest.raises(RuntimeError, match="8192 devices"):
            make_production_mesh(multi_pod=True, shape=(2, 64, 64))
        with pytest.raises(RuntimeError, match="16384 devices"):
            make_production_mesh(shape=(128, 128))

    def test_host_mesh_resolves_on_one_device(self):
        mesh = resolve_mesh(MeshSpec.host())
        assert mesh.shape == {"data": 1, "model": 1}


class TestResolveClientSplit:
    def test_divisor_cohort_splits(self):
        assert resolve_client_split(4, MeshSpec.multi_pod(2, 2, 2)) is True
        assert resolve_client_split(6, MeshSpec.multi_pod(3, 1, 2)) is True

    def test_no_client_axis_or_size_one(self):
        assert resolve_client_split(4, MeshSpec.single_pod(2, 2)) is False
        assert resolve_client_split(4, MeshSpec.multi_pod(1, 2, 2)) is False

    def test_non_divisor_strict_raises_with_pod_count(self):
        with pytest.raises(ValueError, match="size 2 must divide the 5"):
            resolve_client_split(5, MeshSpec.multi_pod(2, 2, 2), strict=True)

    def test_non_divisor_lenient_falls_back(self):
        assert resolve_client_split(5, MeshSpec.multi_pod(2, 2, 2),
                                    strict=False) is False


class TestMakeEngineMeshValidation:
    def test_mesh_requires_spec(self):
        with pytest.raises(ValueError, match="requires a mesh spec"):
            make_engine("mesh", kprime=4)

    def test_mesh_rejects_shards(self):
        with pytest.raises(ValueError, match="client split from the mesh"):
            make_engine("mesh", kprime=4, shards=2, mesh="pods:2x2x2")

    def test_other_backends_reject_mesh(self):
        with pytest.raises(ValueError, match="backend='vmap'"):
            make_engine("vmap", kprime=4, mesh="pods:2x2x2")
        with pytest.raises(ValueError, match="backend='mesh' instead"):
            make_engine("shard_map", kprime=4, mesh="pods:2x2x2")

    def test_auto_clients_spec_resolves_shards(self):
        import jax

        from repro.fl import resolve_shards

        eng = make_engine("mesh", kprime=4, mesh="clients")
        want = resolve_shards(4, len(jax.devices()))
        assert eng.spec == MeshSpec.clients(want)


class TestComposedPspecs:
    def test_cnn_style_names_stay_replicated(self):
        tree = {"conv1": {"w": np.zeros((2, 3, 3, 1, 8)),
                          "b": np.zeros((2, 8))}}
        specs = sh.client_stacked_pspecs(tree, "pod", model_axis="model",
                                         msize=2)
        assert specs["conv1"]["w"] == P("pod", None, None, None, None)
        assert specs["conv1"]["b"] == P("pod", None)

    def test_transformer_names_shard_over_model(self):
        tree = {"mlp": {"wi_gate": np.zeros((2, 8, 16)),
                        "wo": np.zeros((2, 16, 8))}}
        specs = sh.client_stacked_pspecs(tree, "pod", model_axis="model",
                                         msize=2)
        assert specs["mlp"]["wi_gate"] == P("pod", None, "model")
        assert specs["mlp"]["wo"] == P("pod", "model", None)

    def test_msize_one_is_plain_client_stack(self):
        tree = {"mlp": {"wo": np.zeros((2, 16, 8))}}
        specs = sh.client_stacked_pspecs(tree, "clients", model_axis="model",
                                         msize=1)
        assert specs["mlp"]["wo"] == P("clients", None, None)

    def test_rejects_misnamed_model_axis(self):
        with pytest.raises(ValueError, match="named 'model'"):
            sh.client_stacked_pspecs({}, "pod", model_axis="tp", msize=2)


# ---------------------------------------------------------------------------
# Trace-driven availability (replay-from-file)
# ---------------------------------------------------------------------------


def _write_trace(tmp_path, payload):
    p = tmp_path / "trace.json"
    p.write_text(json.dumps(payload))
    return p


TRACE = {
    "period": 10.0,
    "clients": [
        {"duration": 1.0, "online": [[0.0, 10.0]]},
        {"duration": 2.0, "online": [[2.0, 5.0], [7.0, 10.0]]},
    ],
}


class TestTraceAvailability:
    def test_replay_and_wraparound(self, tmp_path):
        path = _write_trace(tmp_path, TRACE)
        av = make_availability(TraceAvailabilityConfig(str(path)), 4, seed=0)
        assert isinstance(av, TraceAvailability)
        assert av.duration(1) == 2.0
        assert av.duration(3) == 2.0  # client 3 replays trace 1 (i % len)
        assert av.is_online(1, 2.0) and not av.is_online(1, 5.0)  # [s, e)
        assert av.is_online(1, 12.5)  # wraps: 12.5 % 10 = 2.5
        assert av.next_online(1, 0.0) == 2.0
        assert av.next_online(1, 5.5) == 7.0
        assert av.next_online(1, 10.5) == 12.0  # next cycle
        # always-on trace client
        assert av.next_online(0, 3.3) == 3.3

    def test_sync_round_duration_waits_for_straggler(self, tmp_path):
        path = _write_trace(tmp_path, TRACE)
        av = make_availability(TraceAvailabilityConfig(str(path)), 2, seed=0)
        # client 1 comes online at t=2 and takes 2.0 -> round ends at 4.0
        assert av.sync_round_duration([0, 1], 0.0) == 4.0

    def test_digest_stamped_and_mismatch_rejected(self, tmp_path):
        path = _write_trace(tmp_path, TRACE)
        av = TraceAvailability(TraceAvailabilityConfig(str(path)), 2)
        fp = dataclasses.asdict(av.cfg)
        assert len(fp["digest"]) == 64  # sha256 in the checkpoint fingerprint
        # pinning a digest detects a changed file
        path.write_text(json.dumps({**TRACE, "period": 11.0}))
        with pytest.raises(ValueError, match="trace changed on disk"):
            TraceAvailability(av.cfg, 2)

    def test_validates_windows(self, tmp_path):
        bad = {"period": 10.0,
               "clients": [{"duration": 1.0, "online": [[5.0, 3.0]]}]}
        with pytest.raises(ValueError, match="windows must be sorted"):
            TraceAvailability(
                TraceAvailabilityConfig(str(_write_trace(tmp_path, bad))), 1)
        with pytest.raises(ValueError, match="no 'clients'"):
            TraceAvailability(
                TraceAvailabilityConfig(
                    str(_write_trace(tmp_path, {"clients": []}))), 1)

    def test_factory_type_switch(self):
        av = make_availability(AvailabilityConfig(), 4, seed=0)
        assert av.n == 4
        with pytest.raises(TypeError, match="availability config"):
            make_availability({"availability": 0.5}, 4, seed=0)
