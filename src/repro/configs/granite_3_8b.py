"""granite-3-8b [hf:ibm-granite/granite-3.0-2b-base family]

40L, d_model=4096, 32 heads (GQA kv=8, head_dim=128), SwiGLU d_ff=12800,
vocab=49155.
"""
from repro.configs.base import LayerSpec, ModelConfig, uniform_pattern

CONFIG = ModelConfig(
    name="granite-3-8b",
    family="dense",
    source="hf:ibm-granite/granite-3.0-8b-base",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=12_800,
    vocab_size=49_155,
    # activation-memory knob: mb=16 halves per-iteration activations
    # (T=16 local-SGD iterations keep the global batch at 256)
    train_micro_batch=16,
    **uniform_pattern(LayerSpec(kind="attn"), 40),
)
