"""olmoe-1b-7b [arXiv:2409.02060]

16L, d_model=2048, 16 heads (kv=16, head_dim=128), vocab=50304.
MoE FFN every layer: 64 experts, top-8, expert d_ff=1024 (SwiGLU).
~1B active / ~7B total parameters.
"""
from repro.configs.base import LayerSpec, ModelConfig, uniform_pattern

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    source="arXiv:2409.02060",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1024,
    vocab_size=50_304,
    n_experts=64,
    top_k=8,
    expert_ff=1024,
    **uniform_pattern(LayerSpec(kind="moe"), 16),
)
