"""musicgen-large [arXiv:2306.05284]

Decoder-only transformer over EnCodec tokens: 48L, d_model=2048, 32 heads
(kv=32, head_dim=64), d_ff=8192, vocab=2048 per codebook, 4 codebooks with
the delay interleaving pattern.  Per the modality carve-out, the EnCodec
conv codec is a stub: the model consumes 4 parallel integer token streams
(summed codebook embeddings) and produces 4 logit heads.  MusicGen's learned
absolute positions are replaced with RoPE (TPU-idiomatic; see DESIGN.md §8).
"""
from repro.configs.base import LayerSpec, ModelConfig, uniform_pattern

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    source="arXiv:2306.05284",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,
    frontend="audio_codebooks",
    n_codebooks=4,
    # decode_32k cache is 1.6 TB at bf16 (48L x 32 kv x 32k x 128 batch);
    # int8 KV quantisation halves it to fit v5e (EXPERIMENTS.md §Perf)
    kv_quant=True,
    **uniform_pattern(LayerSpec(kind="attn"), 48),
)
