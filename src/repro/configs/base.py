"""Config dataclasses for architectures and input shapes.

Every assigned architecture gets one module in this package defining a
``CONFIG`` constant; ``repro.configs.get_config(name)`` resolves it.  The
layer stack is described as ``pattern * n_rep + tail`` so the model code can
``lax.scan`` over pattern repetitions (compile time stays flat in depth).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class LayerSpec:
    """One sublayer in the repeating pattern.

    kind:
      attn        - GQA attention + dense FFN block
      moe         - GQA attention + mixture-of-experts FFN block
      ssm         - Mamba2 (SSD) mixer block (no separate FFN)
      shared_attn - attention + FFN block whose params are SHARED across all
                    repetitions of the pattern (Zamba2-style)
    window: sliding-window size for attention (None = full/global attention)
    rope_base: RoPE theta for this sublayer (gemma3 uses 1M on globals)
    """

    kind: str = "attn"
    window: Optional[int] = None
    rope_base: float = 10_000.0

    def replace(self, **kw) -> "LayerSpec":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio | cnn
    source: str  # citation for the config (paper / model card)

    n_layers: int = 0
    d_model: int = 0
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    vocab_size: int = 0

    # layer schedule: pattern * n_rep + tail  (len == n_layers)
    pattern: Tuple[LayerSpec, ...] = ()
    n_rep: int = 0
    tail: Tuple[LayerSpec, ...] = ()

    # attention extras
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    use_qk_norm: bool = False

    # MoE
    n_experts: int = 0
    top_k: int = 0
    expert_ff: int = 0
    router_groups: int = 1  # routing groups (set = data-axis size in prod)
    capacity_factor: float = 1.25
    moe_impl: str = "dense"  # dense (baseline) | dispatch (hillclimb)

    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    ssm_conv_width: int = 4
    ssm_scan_unroll: int = 1  # >1 unrolls the inter-chunk scan (exact
    #                           cost_analysis counting in the chunk study)

    # modality frontend (carve-out stubs)
    frontend: str = "none"  # none | vision_stub | audio_codebooks
    n_codebooks: int = 0  # musicgen
    n_patches: int = 0  # internvl vision token count
    d_vision: int = 0  # raw patch-embedding dim from the (stubbed) ViT

    # long-context behaviour for the long_500k decode shape
    # native: arch is sub-quadratic as-is (SSM / hybrid)
    # window: run the sliding-window variant (dense archs; documented)
    long_context_mode: str = "window"
    long_context_window: int = 4096

    # numerics
    dtype: str = "bfloat16"
    norm_eps: float = 1e-6

    # model-level kernel policy (DESIGN.md §9): which implementation the
    # model-zoo hot paths (rmsnorm, flash_gqa attention prefill/training)
    # run — "auto" (kernel on TPU, reference elsewhere) / "reference" /
    # "kernel" / "kernel_interpret".  Resolved host-side via
    # repro.kernels.dispatch.resolve_impl, so no runtime branch survives
    # jit.  CLI: --kernel-impl on launch/train.py, launch/serve.py and the
    # examples/ entry points.
    kernel_impl: str = "auto"

    # int8 KV cache (symmetric per-token-per-head quantisation) - halves
    # decode cache HBM; default-on for musicgen-large whose decode_32k
    # cache is 1.6 TB (EXPERIMENTS.md §Perf iteration 8)
    kv_quant: bool = False

    # per-arch train micro-batch (activation-memory knob; T = 256/mb
    # local-SGD iterations keeps the global batch fixed)
    train_micro_batch: int = 32

    # activation checkpointing for the train path: "block" remats every
    # sublayer (backward recomputes attention scores / FFN intermediates -
    # required to fit v5e HBM at train_4k; see EXPERIMENTS.md §Perf for the
    # no-remat ablation), "none" saves everything.
    remat: str = "block"

    # query-block size of the blockwise attention scan (memory/laxity
    # trade-off; the roofline calibration sets it to seq_len so the scan
    # has a single trip and cost_analysis counts it exactly).
    attn_q_block: int = 512

    # sequence (context) parallelism: pin the residual stream to
    # P("data", "model", None) - sequence sharded over the model axis,
    # layer weights replicated.  The hillclimb lever for few-head archs
    # (gemma3-1b: H=4, KV=1) where head/hd tensor parallelism forces
    # involuntary GSPMD resharding (EXPERIMENTS.md §Perf).  Only set by
    # the launch layer inside a mesh context.
    seq_shard: bool = False

    # CNN (paper-faithful ResNet runs)
    cnn_channels: Tuple[int, ...] = ()
    cnn_image_size: int = 32
    cnn_in_channels: int = 3
    n_classes: int = 0

    def __post_init__(self):
        if self.pattern or self.tail:
            total = len(self.pattern) * self.n_rep + len(self.tail)
            assert total == self.n_layers, (
                f"{self.name}: pattern*n_rep+tail = {total} != n_layers {self.n_layers}"
            )

    @property
    def layers(self) -> Tuple[LayerSpec, ...]:
        return tuple(self.pattern) * self.n_rep + tuple(self.tail)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: <=2 pattern reps, d_model<=256, <=4 experts.

        Keeps the *family structure* (same sublayer kinds) so smoke tests
        exercise the real code paths at CPU-friendly sizes.
        """
        d = min(self.d_model, 256) or 256
        heads = max(1, min(self.n_heads, 4))
        kv = max(1, min(self.n_kv_heads, heads))
        hd = 64
        # compress the pattern to <=2 representative sublayers while keeping
        # every distinct sublayer kind (e.g. gemma3's 5xlocal+1xglobal ->
        # 1xlocal+1xglobal; zamba2's 5xssm+shared -> ssm+shared)
        seen, pat = set(), []
        for s in self.pattern:
            sig = (s.kind, s.window is None)
            if sig not in seen and len(pat) < 2:
                seen.add(sig)
                pat.append(s)
        pattern = tuple(pat)
        n_rep = 1 if pattern else 0
        tail = self.tail[: max(0, 2 - len(pattern) * n_rep)]
        n_layers = len(pattern) * n_rep + len(tail)
        kw = dict(
            name=self.name + "-smoke",
            n_layers=n_layers,
            d_model=d,
            n_heads=heads,
            n_kv_heads=kv,
            head_dim=hd,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512) if self.vocab_size else 0,
            pattern=pattern,
            n_rep=n_rep,
            tail=tail,
            dtype="float32",
        )
        if self.n_experts:
            kw.update(
                n_experts=min(self.n_experts, 4),
                top_k=min(self.top_k, 2),
                expert_ff=min(self.expert_ff, 128),
            )
        if self.ssm_state:
            kw.update(ssm_state=min(self.ssm_state, 16), ssm_chunk=32)
        if self.n_patches:
            kw.update(n_patches=8, d_vision=min(self.d_vision, 128))
        if self.cnn_channels:
            kw.update(cnn_channels=tuple(min(c, 16) for c in self.cnn_channels))
        return self.replace(**kw)


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


TRAIN_4K = InputShape("train_4k", 4_096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32_768, 128, "decode")
LONG_500K = InputShape("long_500k", 524_288, 1, "decode")

INPUT_SHAPES = {s.name: s for s in [TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K]}


def uniform_pattern(spec: LayerSpec, n_layers: int) -> dict:
    return dict(pattern=(spec,), n_rep=n_layers, tail=())
