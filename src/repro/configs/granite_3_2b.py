"""granite-3-2b [hf:ibm-granite/granite-3.0-2b-base]

40L, d_model=2048, 32 heads (GQA kv=8, head_dim=64), SwiGLU d_ff=8192,
vocab=49155.
"""
from repro.configs.base import LayerSpec, ModelConfig, uniform_pattern

CONFIG = ModelConfig(
    name="granite-3-2b",
    family="dense",
    source="hf:ibm-granite/granite-3.0-2b-base",
    n_layers=40,
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    head_dim=64,
    d_ff=8192,
    vocab_size=49_155,
    **uniform_pattern(LayerSpec(kind="attn"), 40),
)
