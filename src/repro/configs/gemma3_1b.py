"""gemma3-1b [hf:google/gemma-3-1b-pt]

26L, d_model=1152, 4 heads (GQA kv=1, head_dim=256), d_ff=6912 (GeGLU),
vocab=262144.  5:1 local:global attention; locals use sliding window 512 with
RoPE base 10k, globals are full attention with RoPE base 1M (128k context).
26 = 4 x (5 local + 1 global) + 2 local tail.
"""
from repro.configs.base import LayerSpec, ModelConfig

_LOCAL = LayerSpec(kind="attn", window=512, rope_base=10_000.0)
_GLOBAL = LayerSpec(kind="attn", window=None, rope_base=1_000_000.0)

CONFIG = ModelConfig(
    name="gemma3-1b",
    family="dense",
    source="hf:google/gemma-3-1b-pt",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab_size=262_144,
    pattern=(_LOCAL,) * 5 + (_GLOBAL,),
    n_rep=4,
    tail=(_LOCAL, _LOCAL),
    use_qk_norm=True,
    long_context_mode="window",
    long_context_window=4096,
)
