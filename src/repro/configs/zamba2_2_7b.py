"""zamba2-2.7b [arXiv:2411.15242]

Hybrid: 54 layers, d_model=2560, Mamba2 backbone (ssm_state=64) with a
SHARED attention+MLP block (32 heads, kv=32, head_dim=80, d_ff=10240,
params reused at every invocation) interleaved every 6th layer:
pattern = (ssm x5, shared_attn) x 9.  vocab=32000.  Zamba2's per-invocation
LoRA deltas on the shared block are omitted (see DESIGN.md §8).
Sub-quadratic natively via the SSM backbone + single shared windowless
attention over the running context: for long_500k the shared block uses the
sliding-window variant while the SSM path is recurrent.
"""
from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    source="arXiv:2411.15242",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    head_dim=80,
    d_ff=10_240,
    vocab_size=32_000,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=256,
    train_micro_batch=16,
    pattern=(LayerSpec(kind="ssm"),) * 5 + (LayerSpec(kind="shared_attn"),),
    n_rep=9,
    tail=(),
    long_context_mode="native",
    long_context_window=4096,
)
