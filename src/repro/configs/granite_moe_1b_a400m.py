"""granite-moe-1b-a400m [hf:ibm-granite/granite-3.0-1b-a400m-base]

24L, d_model=1024, 16 heads (GQA kv=8, head_dim=64), vocab=49155.
MoE FFN: 32 experts, top-8, expert d_ff=512 (SwiGLU).  ~400M active params.
"""
from repro.configs.base import LayerSpec, ModelConfig, uniform_pattern

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49_155,
    n_experts=32,
    top_k=8,
    expert_ff=512,
    **uniform_pattern(LayerSpec(kind="moe"), 24),
)
