"""mamba2-2.7b [arXiv:2405.21060]

64L, d_model=2560, attention-free SSD (state-space duality) mixer,
ssm_state=128, head_dim=64, expand=2, vocab=50280.  Sub-quadratic natively:
long_500k decode runs the recurrent state update (O(1) in sequence length).
"""
from repro.configs.base import LayerSpec, ModelConfig, uniform_pattern

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    source="arXiv:2405.21060",
    n_layers=64,
    d_model=2560,
    d_ff=0,
    vocab_size=50_280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=256,
    long_context_mode="native",
    train_micro_batch=16,
    **uniform_pattern(LayerSpec(kind="ssm"), 64),
)
