"""Architecture config registry.

``get_config("gemma3-1b")`` returns the full assigned config;
``get_config("gemma3-1b", reduced=True)`` the CPU-smoke variant.
"""
from __future__ import annotations

import importlib

from repro.configs.base import (  # noqa: F401
    INPUT_SHAPES,
    DECODE_32K,
    LONG_500K,
    PREFILL_32K,
    TRAIN_4K,
    InputShape,
    LayerSpec,
    ModelConfig,
)

_MODULES = {
    "gemma3-1b": "repro.configs.gemma3_1b",
    "musicgen-large": "repro.configs.musicgen_large",
    "granite-3-2b": "repro.configs.granite_3_2b",
    "granite-3-8b": "repro.configs.granite_3_8b",
    "mamba2-2.7b": "repro.configs.mamba2_2_7b",
    "zamba2-2.7b": "repro.configs.zamba2_2_7b",
    "olmoe-1b-7b": "repro.configs.olmoe_1b_7b",
    "gemma2-9b": "repro.configs.gemma2_9b",
    "granite-moe-1b-a400m": "repro.configs.granite_moe_1b_a400m",
    "internvl2-2b": "repro.configs.internvl2_2b",
    "resnet-cifar": "repro.configs.resnet_cifar",
}

ARCH_NAMES = tuple(n for n in _MODULES if n != "resnet-cifar")


def get_config(name: str, reduced: bool = False) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown architecture {name!r}; known: {sorted(_MODULES)}")
    cfg = importlib.import_module(_MODULES[name]).CONFIG
    return cfg.reduced() if reduced else cfg


def get_shape(name: str) -> InputShape:
    return INPUT_SHAPES[name]
