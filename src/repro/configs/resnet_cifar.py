"""ResNet-family CNN for the paper-faithful pFedSOP reproduction.

The paper trains ResNet-18 (CIFAR-10) and ResNet-9 (CIFAR-100 / TinyImageNet)
with categorical cross-entropy.  BatchNorm is replaced by GroupNorm: batch
statistics leak across FL clients under vmap'd simulation and are a known
confounder in FL reproductions (see DESIGN.md §8).

``RESNET9_CIFAR100`` / ``RESNET18_CIFAR10`` are the paper-scale configs;
``SMALL_CNN`` is the CPU-budget variant used by the benchmark harness
(same family, reduced width).
"""
from repro.configs.base import ModelConfig

RESNET18_CIFAR10 = ModelConfig(
    name="resnet18-cifar10",
    family="cnn",
    source="He et al. 2016 / pFedSOP Sec. V-B",
    cnn_channels=(64, 128, 256, 512),
    cnn_image_size=32,
    cnn_in_channels=3,
    n_classes=10,
    dtype="float32",
)

RESNET9_CIFAR100 = ModelConfig(
    name="resnet9-cifar100",
    family="cnn",
    source="He et al. 2016 / pFedSOP Sec. V-B",
    cnn_channels=(64, 128, 256),
    cnn_image_size=32,
    cnn_in_channels=3,
    n_classes=100,
    dtype="float32",
)

SMALL_CNN = ModelConfig(
    name="small-cnn",
    family="cnn",
    source="reduced ResNet family (CPU budget)",
    cnn_channels=(16, 32),
    cnn_image_size=16,
    cnn_in_channels=3,
    n_classes=10,
    dtype="float32",
)

CONFIG = RESNET9_CIFAR100
