"""gemma2-9b [arXiv:2408.00118]

42L, d_model=3584, 16 heads (GQA kv=8, head_dim=256), GeGLU d_ff=14336,
vocab=256000.  Alternating local (window 4096) / global attention,
attention logit softcap 50.0 and final-logit softcap 30.0.
"""
from repro.configs.base import LayerSpec, ModelConfig

_LOCAL = LayerSpec(kind="attn", window=4096, rope_base=10_000.0)
_GLOBAL = LayerSpec(kind="attn", window=None, rope_base=10_000.0)

CONFIG = ModelConfig(
    name="gemma2-9b",
    family="dense",
    source="arXiv:2408.00118",
    n_layers=42,
    d_model=3584,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=14_336,
    vocab_size=256_000,
    pattern=(_LOCAL, _GLOBAL),
    n_rep=21,
    tail=(),
    attn_softcap=50.0,
    final_softcap=30.0,
    long_context_mode="window",
    long_context_window=4096,
)
