"""internvl2-2b [arXiv:2404.16821]

VLM: InternViT-300M vision encoder + MLP projector + InternLM2-1.8B language
backbone.  Per the modality carve-out, the ViT is a stub — input_specs()
provides precomputed patch embeddings (B, 256, 1024); the framework owns the
projector (1024 -> d_model) and the language decoder: 24L, d_model=2048,
16 heads (GQA kv=8, head_dim=128), SwiGLU d_ff=8192, vocab=92553.
"""
from repro.configs.base import LayerSpec, ModelConfig, uniform_pattern

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    source="arXiv:2404.16821",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=92_553,
    frontend="vision_stub",
    n_patches=256,
    d_vision=1024,
    train_micro_batch=16,
    **uniform_pattern(LayerSpec(kind="attn"), 24),
)
