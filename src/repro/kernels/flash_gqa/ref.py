"""Pure-jnp oracle for flash_gqa: causal GQA attention with optional
sliding window and logit softcap.  Materialises the full score matrix -
only usable at test sizes."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_gqa_ref(q, k, v, window=None, softcap=None, scale=None):
    """q: (B,H,S,D), k/v: (B,KV,S,D) -> (B,H,S,D).  Causal."""
    b, h, s, d = q.shape
    kv = k.shape[1]
    g = h // kv
    sc = scale if scale is not None else d**-0.5
    qg = q.reshape(b, kv, g, s, d).astype(jnp.float32)
    scores = jnp.einsum("bkgqd,bktd->bkgqt", qg, k.astype(jnp.float32)) * sc
    if softcap is not None:
        scores = softcap * jnp.tanh(scores / softcap)
    qi = jnp.arange(s)[:, None]
    ki = jnp.arange(s)[None, :]
    mask = ki <= qi
    if window is not None:
        mask &= (qi - ki) < window
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bkgqt,bktd->bkgqd", w, v.astype(jnp.float32))
    return o.reshape(b, h, s, d).astype(q.dtype)
