"""Public jit'd wrapper for flash_gqa.

Accepts the model-layer layout (B, S, H, D) and transposes to the kernel's
(B, H, S, D).  ``interpret=True`` runs the kernel body in Python on CPU
(the CI validation path); on TPU the same call lowers to Mosaic.

Call sites: the model zoo — ``repro.models.attention.attention_fwd`` (the
training/prefill path behind every transformer/MoE/SSM-hybrid stack and
the serving prefill) dispatches here when ``ModelConfig.kernel_impl``
resolves to a kernel impl (DESIGN.md §9) — plus tests/test_kernels.py,
tests/test_model_dispatch.py and ``benchmarks/run.py --only kernels /
model-fwd``.

Block pruning: with a sliding window W << S most (q_block, k_block) grid
steps are fully masked.  ``prune_window`` (default on) shrinks the KV grid
axis to nkp = ceil((W + BQ)/BK) + 1 blocks per q row via a shifted k index
map — see ``kernel.flash_gqa_grid`` for the exact grid and
tests/test_kernels.py::TestFlashGQAPruned for the parity sweep.

Differentiable, with a dispatched backward (``bwd`` knob, DESIGN.md §9
``flash_gqa_bwd``):

  "reference"  recomputes attention q-block by q-block (same math as the
               oracle, one ``jax.vjp`` per block inside a ``lax.scan``
               that accumulates dk/dv in the carry), so backward live
               memory stays O(S·BQ) — no full O(S²) score tensor.
  kernel imps  the forward additionally emits the per-row LSE residual
               and the backward runs the fused two-pass flash backward
               kernel (``kernel.flash_gqa_bwd_pallas``: a dq pass over
               the forward's window-pruned grid, a dk/dv pass over the
               q-blocks visible to each k-block).

Under ``remat="block"`` the recomputed forward stays on the kernel path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.dispatch import resolve_impl
from repro.kernels.flash_gqa.kernel import (_block_sizes,
                                            flash_gqa_bwd_pallas,
                                            flash_gqa_pallas)
from repro.kernels.flash_gqa.ref import NEG_INF


@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(3, 4, 5, 6, 7, 8, 9, 10))
def _flash_gqa(q, k, v, window, softcap, scale, bq, bk, interpret,
               prune_window, bwd):
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    out = flash_gqa_pallas(qt, kt, vt, window=window, softcap=softcap,
                           scale=scale, bq=bq, bk=bk, interpret=interpret,
                           prune_window=prune_window)
    return jnp.swapaxes(out, 1, 2)


def _flash_gqa_fwd(q, k, v, window, softcap, scale, bq, bk, interpret,
                   prune_window, bwd):
    if resolve_impl(bwd, "flash_gqa_bwd") == "reference":
        out = _flash_gqa(q, k, v, window, softcap, scale, bq, bk, interpret,
                         prune_window, bwd)
        return out, (q, k, v, None, None)
    # Kernel backward: run the residual forward so the backward passes get
    # the per-row LSE without a second online-softmax sweep.
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    out, lse = flash_gqa_pallas(qt, kt, vt, window=window, softcap=softcap,
                                scale=scale, bq=bq, bk=bk,
                                interpret=interpret,
                                prune_window=prune_window,
                                return_residual=True)
    return jnp.swapaxes(out, 1, 2), (q, k, v, jnp.swapaxes(out, 1, 2), lse)


def _flash_gqa_bwd(window, softcap, scale, bq, bk, interpret, prune_window,
                   bwd, res, g):
    impl = resolve_impl(bwd, "flash_gqa_bwd")
    if impl != "reference":
        q, k, v, out, lse = res  # model layout (B,S,H,D) / lse (B,H,S)
        dq, dk, dv = flash_gqa_bwd_pallas(
            jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
            jnp.swapaxes(v, 1, 2), jnp.swapaxes(out, 1, 2), lse,
            jnp.swapaxes(g, 1, 2), window=window, softcap=softcap,
            scale=scale, bq=bq, bk=bk,
            interpret=impl == "kernel_interpret" or interpret,
            prune_window=prune_window)
        return (jnp.swapaxes(dq, 1, 2), jnp.swapaxes(dk, 1, 2),
                jnp.swapaxes(dv, 1, 2))
    return _flash_gqa_bwd_reference(window, softcap, scale, bq, bk, res, g)


def _flash_gqa_bwd_reference(window, softcap, scale, bq, bk, res, g):
    """Blockwise backward: for each q block, recompute its attention (the
    oracle math, f32) and pull the cotangent back through it; dk/dv are
    accumulated across blocks in the scan carry.  Positions are the
    canonical arange(S) the kernel's masks assume."""
    q, k, v = res[:3]  # (B,S,H,D), (B,S,KV,D)
    b, s, h, d = q.shape
    kvh = k.shape[2]
    grp = h // kvh
    sc = scale if scale is not None else d**-0.5

    qb, _, nb, _ = _block_sizes(s, bq, bk)

    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    kpos = jnp.arange(s)

    def block_out(qblk, kk, vv, qpos):
        """qblk (B,qb,H,D) f32 attending over all S keys -> (B,qb,H,D)."""
        qg = qblk.reshape(b, qb, kvh, grp, d)
        sc_ = jnp.einsum("bqkgd,btkd->bqkgt", qg, kk) * sc
        if softcap is not None:
            sc_ = softcap * jnp.tanh(sc_ / softcap)
        mask = kpos[None, :] <= qpos[:, None]
        if window is not None:
            mask &= (qpos[:, None] - kpos[None, :]) < window
        sc_ = jnp.where(mask[None, :, None, None, :], sc_, NEG_INF)
        w = jax.nn.softmax(sc_, axis=-1)
        o = jnp.einsum("bqkgt,btkd->bqkgd", w, vv)
        return o.reshape(b, qb, h, d)

    q_blocks = jnp.moveaxis(
        q.astype(jnp.float32).reshape(b, nb, qb, h, d), 1, 0)
    g_blocks = jnp.moveaxis(
        g.astype(jnp.float32).reshape(b, nb, qb, h, d), 1, 0)
    pos_blocks = kpos.reshape(nb, qb)

    def body(carry, inp):
        dk, dv = carry
        qblk, gblk, qpos = inp
        _, vjp = jax.vjp(
            lambda qq, kk, vv: block_out(qq, kk, vv, qpos), qblk, kf, vf)
        dqb, dki, dvi = vjp(gblk)
        return (dk + dki, dv + dvi), dqb

    zeros = (jnp.zeros_like(kf), jnp.zeros_like(vf))
    (dk, dv), dq_blocks = jax.lax.scan(
        body, zeros, (q_blocks, g_blocks, pos_blocks))
    dq = jnp.moveaxis(dq_blocks, 0, 1).reshape(b, s, h, d)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


_flash_gqa.defvjp(_flash_gqa_fwd, _flash_gqa_bwd)


@functools.partial(
    jax.jit,
    static_argnames=("window", "softcap", "scale", "bq", "bk", "interpret",
                     "prune_window", "bwd"),
)
def flash_gqa(q, k, v, window=None, softcap=None, scale=None,
              bq: int = 512, bk: int = 512, interpret: bool = False,
              prune_window: bool = True, bwd: str = "auto"):
    """q: (B,S,H,D), k/v: (B,S,KV,D) -> (B,S,H,D).  Causal GQA attention.

    ``bwd`` selects the backward impl (dispatch vocabulary, kernel
    ``flash_gqa_bwd``): "reference" keeps the blockwise scan-of-VJPs,
    the kernel impls run the fused flash backward; "auto" resolves from
    the host platform like every other dispatched kernel.
    """
    return _flash_gqa(q, k, v, window, softcap, scale, bq, bk, interpret,
                      prune_window, bwd)
