"""Public jit'd wrapper for flash_gqa.

Accepts the model-layer layout (B, S, H, D) and transposes to the kernel's
(B, H, S, D).  ``interpret=True`` runs the kernel body in Python on CPU
(the CI validation path); on TPU the same call lowers to Mosaic.

Call sites: tests/test_kernels.py and ``benchmarks/run.py --only kernels``
only — the model zoo (``repro.models.attention``) still runs its own
blockwise-jnp attention (same math, mirrored by ref.py).  Routing the
models through the DESIGN.md §9 dispatch layer is a ROADMAP open item.

Block-pruning note (hillclimb lever, EXPERIMENTS.md §Perf): with a sliding
window W << S, most (q_block, k_block) grid steps are fully masked.  The
kernel still visits them (grid shape is static); the pruned variant reduces
nk to ceil((W + BQ)/BK) + 1 blocks per q row by shifting the k index map -
added during the perf pass (see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_gqa.kernel import flash_gqa_pallas


@functools.partial(
    jax.jit, static_argnames=("window", "softcap", "scale", "bq", "bk", "interpret")
)
def flash_gqa(q, k, v, window=None, softcap=None, scale=None,
              bq: int = 512, bk: int = 512, interpret: bool = False):
    """q: (B,S,H,D), k/v: (B,S,KV,D) -> (B,S,H,D).  Causal GQA attention."""
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    out = flash_gqa_pallas(qt, kt, vt, window=window, softcap=softcap,
                           scale=scale, bq=bq, bk=bk, interpret=interpret)
    return jnp.swapaxes(out, 1, 2)
