"""Blockwise online-softmax GQA attention - Pallas TPU kernel.

The canonical flash-attention tiling adapted for the assigned archs:

  grid = (B * H, S/BQ, S/BK)   - the KV block index is the INNERMOST grid
  dimension; TPU executes the grid sequentially per core, so the running
  (m, l, acc) online-softmax state lives in VMEM scratch and persists
  across the KV iterations of one (batch-head, q-block) pair.

  q tile   (BQ, D)  VMEM     k/v tiles (BK, D) VMEM
  scratch: m (BQ,1) l (BQ,1) acc (BQ, D) - all f32.

GQA: query head h reads KV head h // (H/KV) via the k/v BlockSpec index
maps - no KV replication in HBM.  Sliding window + causality are enforced
element-wise inside each tile via broadcasted iota; fully-masked tiles
contribute exp(-inf) = 0 (the ops.py wrapper documents the block-pruning
hillclimb that skips them outright).

Softcap (gemma2) is applied to the scaled scores before masking, matching
repro/models/attention.py.

D (head_dim) is 64..256 for all assigned archs - lane-aligned; BQ/BK are
multiples of 8 (sublane).  VMEM budget at BQ=BK=512, D=256, f32:
q 512x256x4 = 512 KiB, k+v 1 MiB, acc 512 KiB - comfortably inside 16 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, window, softcap, bq: int, bk: int, nk: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32) * scale  # (BQ, D)
    k = k_ref[0, 0].astype(jnp.float32)  # (BK, D)
    s = q @ k.T  # (BQ, BK)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = k_pos <= q_pos
    if window is not None:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]  # (BQ, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    # rows with no valid key yet keep m=NEG_INF; clamp so alpha stays finite
    alpha = jnp.exp(jnp.minimum(m_prev - m_new, 0.0))
    p = jnp.exp(s - m_new)  # masked entries: exp(NEG_INF - m) = 0
    l_new = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = alpha * acc_scr[...] + p @ v_ref[0, 0].astype(jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = l_scr[...]
        o_ref[0] = (acc_scr[...] / jnp.where(l == 0.0, 1.0, l)).astype(o_ref.dtype)


def flash_gqa_pallas(q, k, v, window=None, softcap=None, scale=None,
                     bq: int = 512, bk: int = 512, interpret: bool = False):
    """q: (B,H,S,D), k/v: (B,KV,S,D) -> (B,H,S,D).  Causal GQA."""
    b, h, s, d = q.shape
    kv = k.shape[1]
    assert h % kv == 0
    g = h // kv
    sc = scale if scale is not None else d**-0.5

    bq = min(bq, s)
    while s % bq:
        bq //= 2
    bk = min(bk, s)
    while s % bk:
        bk //= 2
    nq, nk = s // bq, s // bk

    qf = q.reshape(b * h, s, d)
    grid = (b * h, nq, nk)

    kernel = functools.partial(
        _flash_kernel, scale=sc, window=window, softcap=softcap,
        bq=bq, bk=bk, nk=nk,
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, qi, ki: (bh, qi, 0)),
            # GQA: map the flattened batch-head index to (batch, kv head)
            pl.BlockSpec((1, 1, bk, d), lambda bh, qi, ki: (bh // h, (bh % h) // g, ki, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda bh, qi, ki: (bh // h, (bh % h) // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(qf, k, v)
    return out.reshape(b, h, s, d)
