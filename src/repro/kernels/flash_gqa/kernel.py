"""Blockwise online-softmax GQA attention - Pallas TPU kernel.

The canonical flash-attention tiling adapted for the assigned archs:

  grid = (B * H, S/BQ, nk)     - the KV block index is the INNERMOST grid
  dimension; TPU executes the grid sequentially per core, so the running
  (m, l, acc) online-softmax state lives in VMEM scratch and persists
  across the KV iterations of one (batch-head, q-block) pair.

  q tile   (BQ, D)  VMEM     k/v tiles (BK, D) VMEM
  scratch: m (BQ,1) l (BQ,1) acc (BQ, D) - all f32.

GQA: query head h reads KV head h // (H/KV) via the k/v BlockSpec index
maps - no KV replication in HBM.  Sliding window + causality are enforced
element-wise inside each tile via broadcasted iota; fully-masked tiles
contribute exp(-inf) = 0.

Window-pruned grid (``prune_window``, default on): with a sliding window
W << S most (q_block, k_block) steps are fully masked, so for windowed
layers the KV grid axis shrinks from nk = S/BK to

  nkp = min(nk, ceil((W + BQ) / BK) + 1)

blocks per q row and the k/v index maps shift to the window: for q block
qi the visited k blocks are max(0, last - nkp + 1) .. last with
last = (qi*BQ + BQ - 1) // BK.  Coverage is exact: every k block holding
a key inside the union of the rows' windows (k in (qi*BQ - W, qi*BQ +
BQ - 1]) lands in that range, earlier blocks are fully outside the
window, and any visited block beyond a row's window is element-masked as
before.  ``flash_gqa_grid`` exposes the resulting (nq, nk_visited) pair -
it is the same computation ``flash_gqa_pallas`` builds its grid from, so
benchmarks/tests assert block-count wins against it directly.

Softcap (gemma2) is applied to the scaled scores before masking, matching
repro/models/attention.py.

D (head_dim) is 64..256 for all assigned archs - lane-aligned; BQ/BK are
multiples of 8 (sublane).  VMEM budget at BQ=BK=512, D=256, f32:
q 512x256x4 = 512 KiB, k+v 1 MiB, acc 512 KiB - comfortably inside 16 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _block_sizes(s: int, bq: int, bk: int):
    """Clamp/halve the requested block sizes until they divide S."""
    bq = min(bq, s)
    while s % bq:
        bq //= 2
    bk = min(bk, s)
    while s % bk:
        bk //= 2
    return bq, bk, s // bq, s // bk


def _first_kv_block(qi, bq: int, bk: int, nkp: int):
    """First visited k-block for q block ``qi`` under the pruned grid.

    The single source for the window shift: both the kernel body's mask
    positions and the k/v BlockSpec index maps derive the true k-block
    index as ``_first_kv_block(qi, ...) + j`` — they MUST agree, or the
    element mask would be computed for a different tile than the one the
    BlockSpec loaded.
    """
    last = (qi * bq + bq - 1) // bk
    return jnp.maximum(last - (nkp - 1), 0)


def flash_gqa_grid(s: int, bq: int = 512, bk: int = 512, window=None,
                   prune_window: bool = True):
    """(nq, nk_visited) for the given sequence/window/tiling.

    ``nk_visited`` is the number of KV grid steps each q row actually
    executes — pruned to ceil((W+BQ)/BK)+1 for sliding-window layers when
    ``prune_window`` (the asymptotic win: O(S·W) instead of O(S²) tiles).
    This is the exact grid ``flash_gqa_pallas`` launches.
    """
    bq, bk, nq, nk = _block_sizes(s, bq, bk)
    if window is None or not prune_window:
        return nq, nk
    return nq, min(nk, pl.cdiv(window + bq, bk) + 1)


def flash_gqa_bwd_grid(s: int, bq: int = 512, bk: int = 512, window=None,
                       prune_window: bool = True):
    """Visited block counts of the two backward passes: (nk_dq, nq_dkv).

    The dq pass reuses the forward's (possibly window-pruned) KV grid, so
    ``nk_dq`` equals the forward's ``nk_visited``.  The dk/dv pass sweeps,
    per k-block, the q-blocks that can see it: under a sliding window
    that is min(nq, ceil((W + BK)/BQ) + 1) — the forward's pruning with
    the roles of BQ/BK swapped — and nq otherwise.  These are the exact
    extents ``flash_gqa_bwd_pallas`` launches, so benches/tests assert
    the backward's O(S·W) tile count against it directly.
    """
    bq, bk, nq, nk = _block_sizes(s, bq, bk)
    _, nkp = flash_gqa_grid(s, bq, bk, window, prune_window)
    if window is not None and prune_window:
        nqv = min(nq, pl.cdiv(window + bk, bq) + 1)
    else:
        nqv = nq
    return nkp, nqv


def _mask_block(qi, ki, bq: int, bk: int, window):
    """The (BQ, BK) causal/window element mask for tile (qi, ki) — the one
    mask shared by the forward kernel and both backward passes (positions
    are the canonical arange(S) every model entry point passes)."""
    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = k_pos <= q_pos
    if window is not None:
        mask &= (q_pos - k_pos) < window
    return mask


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *rest, scale: float, window,
                  softcap, bq: int, bk: int, nkp: int, pruned: bool,
                  residual: bool = False):
    if residual:
        lse_ref, m_scr, l_scr, acc_scr = rest
    else:
        m_scr, l_scr, acc_scr = rest
    qi = pl.program_id(1)
    j = pl.program_id(2)  # pruned: offset into the visited window blocks

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    ki = _first_kv_block(qi, bq, bk, nkp) + j if pruned else j  # true k-block

    q = q_ref[0].astype(jnp.float32) * scale  # (BQ, D)
    k = k_ref[0, 0].astype(jnp.float32)  # (BK, D)
    s = q @ k.T  # (BQ, BK)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    s = jnp.where(_mask_block(qi, ki, bq, bk, window), s, NEG_INF)

    m_prev = m_scr[...]  # (BQ, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    # rows with no valid key yet keep m=NEG_INF; clamp so alpha stays finite
    alpha = jnp.exp(jnp.minimum(m_prev - m_new, 0.0))
    p = jnp.exp(s - m_new)  # masked entries: exp(NEG_INF - m) = 0
    l_new = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = alpha * acc_scr[...] + p @ v_ref[0, 0].astype(jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(j == nkp - 1)
    def _finalize():
        l = l_scr[...]
        o_ref[0] = (acc_scr[...] / jnp.where(l == 0.0, 1.0, l)).astype(o_ref.dtype)
        if residual:
            # log-sum-exp per row: the backward passes recompute the
            # normalized probabilities as exp(s - L) in one shot, no
            # second online-softmax sweep.  Causal masking guarantees at
            # least one valid key per row (k = q), so l > 0 always; the
            # where() mirrors the output guard for safety.
            lse_ref[0] = (m_scr[...] +
                          jnp.log(jnp.where(l == 0.0, 1.0, l)))[:, 0]


def flash_gqa_pallas(q, k, v, window=None, softcap=None, scale=None,
                     bq: int = 512, bk: int = 512, interpret: bool = False,
                     prune_window: bool = True, return_residual: bool = False):
    """q: (B,H,S,D), k/v: (B,KV,S,D) -> (B,H,S,D).  Causal GQA.

    With ``return_residual`` also emits the per-row log-sum-exp
    (B,H,S) f32 — the forward residual the fused backward kernels need
    to recompute probabilities without a second online-softmax sweep.
    """
    b, h, s, d = q.shape
    kv = k.shape[1]
    assert h % kv == 0
    g = h // kv
    sc = scale if scale is not None else d**-0.5

    bq, bk, nq, nk = _block_sizes(s, bq, bk)
    _, nkp = flash_gqa_grid(s, bq, bk, window, prune_window)
    pruned = nkp < nk

    qf = q.reshape(b * h, s, d)
    grid = (b * h, nq, nkp)

    if pruned:
        # shift the KV grid axis to the window: blocks last-nkp+1 .. last
        def kv_index(bh, qi, j):
            return (bh // h, (bh % h) // g, _first_kv_block(qi, bq, bk, nkp) + j, 0)
    else:
        def kv_index(bh, qi, j):
            return (bh // h, (bh % h) // g, j, 0)

    kernel = functools.partial(
        _flash_kernel, scale=sc, window=window, softcap=softcap,
        bq=bq, bk=bk, nkp=nkp, pruned=pruned, residual=return_residual,
    )
    out_specs = pl.BlockSpec((1, bq, d), lambda bh, qi, j: (bh, qi, 0))
    out_shape = jax.ShapeDtypeStruct((b * h, s, d), q.dtype)
    if return_residual:
        out_specs = (out_specs,
                     pl.BlockSpec((1, bq), lambda bh, qi, j: (bh, qi)))
        out_shape = (out_shape,
                     jax.ShapeDtypeStruct((b * h, s), jnp.float32))
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, qi, j: (bh, qi, 0)),
            # GQA: map the flattened batch-head index to (batch, kv head)
            pl.BlockSpec((1, 1, bk, d), kv_index),
            pl.BlockSpec((1, 1, bk, d), kv_index),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(qf, k, v)
    if return_residual:
        out, lse = out
        return out.reshape(b, h, s, d), lse.reshape(b, h, s)
    return out.reshape(b, h, s, d)


# ---------------------------------------------------------------------------
# Fused backward: recompute-p flash backward in two window-pruned passes.
#
# Standard flash-attention backward with the LSE residual: each tile
# recomputes p = exp(s_masked - L) in one shot (no online-softmax sweep),
# then with delta = rowsum(dO * O) the softmax backward collapses to
#
#   dp = dO @ v.T          ds = p * (dp - delta)
#   dq += (ds @ k) * scale dk += ds.T @ (q * scale)   dv += p.T @ dO
#
# (softcap inserts ds *= 1 - tanh^2(s_raw / cap) between ds and the
# dq/dk products, mirroring the forward's tanh).
#
# Two kernels because dq and dk/dv reduce over opposite grid axes:
#   dq pass : grid (B*H,  nq, nkp)     - the forward's own pruned grid,
#             dq accumulates across the visited KV blocks in scratch.
#   dkv pass: grid (B*KV, nk, G*nqv)   - dk/dv accumulate across the G
#             query heads of the group and the nqv q-blocks that can see
#             this k-block.  nqv mirrors the forward's pruning with the
#             roles of BQ/BK swapped: ceil((W + BK) / BQ) + 1 visited
#             q-blocks under a sliding window, nq otherwise.  The first
#             visited q-block (ki*BK)//BQ also prunes the causal lower
#             triangle for free in the full-attention case; the tail
#             past nq-1 is clamped in the index maps and its
#             accumulation guarded out (clamping alone would double
#             count block nq-1).
# ---------------------------------------------------------------------------


def _flash_bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                         dq_ref, dq_scr, *, scale: float, window, softcap,
                         bq: int, bk: int, nkp: int, pruned: bool):
    qi = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        dq_scr[...] = jnp.zeros_like(dq_scr)

    ki = _first_kv_block(qi, bq, bk, nkp) + j if pruned else j

    qs = q_ref[0].astype(jnp.float32) * scale  # (BQ, D)
    k = k_ref[0, 0].astype(jnp.float32)  # (BK, D)
    s_raw = jnp.dot(qs, k.T, preferred_element_type=jnp.float32)
    if softcap is not None:
        t = jnp.tanh(s_raw / softcap)
        s = softcap * t
    else:
        s = s_raw
    s = jnp.where(_mask_block(qi, ki, bq, bk, window), s, NEG_INF)

    p = jnp.exp(s - lse_ref[0][:, None])  # masked entries -> exp(-inf) = 0
    do = do_ref[0].astype(jnp.float32)  # (BQ, D)
    dp = jnp.dot(do, v_ref[0, 0].astype(jnp.float32).T,
                 preferred_element_type=jnp.float32)
    ds = p * (dp - delta_ref[0][:, None])
    if softcap is not None:
        ds = ds * (1.0 - t * t)
    dq_scr[...] += jnp.dot(ds, k, preferred_element_type=jnp.float32)

    @pl.when(j == nkp - 1)
    def _finalize():
        dq_ref[0] = (dq_scr[...] * scale).astype(dq_ref.dtype)


def _flash_bwd_dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                          dk_ref, dv_ref, dk_scr, dv_scr, *, scale: float,
                          window, softcap, bq: int, bk: int, nq: int,
                          nqv: int, g: int):
    ki = pl.program_id(1)
    t = pl.program_id(2)  # decomposes to (group head, visited q-block)

    @pl.when(t == 0)
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    # True q-block for this step; the index maps clamp it to nq-1, the
    # accumulation guard below skips the clamped duplicates.
    qi = (ki * bk) // bq + t % nqv

    qs = q_ref[0].astype(jnp.float32) * scale  # (BQ, D)
    k = k_ref[0, 0].astype(jnp.float32)  # (BK, D)
    s_raw = jnp.dot(qs, k.T, preferred_element_type=jnp.float32)
    if softcap is not None:
        tc = jnp.tanh(s_raw / softcap)
        s = softcap * tc
    else:
        s = s_raw
    s = jnp.where(_mask_block(qi, ki, bq, bk, window), s, NEG_INF)

    p = jnp.exp(s - lse_ref[0][:, None])  # (BQ, BK)
    do = do_ref[0].astype(jnp.float32)  # (BQ, D)
    dp = jnp.dot(do, v_ref[0, 0].astype(jnp.float32).T,
                 preferred_element_type=jnp.float32)
    ds = p * (dp - delta_ref[0][:, None])
    if softcap is not None:
        ds = ds * (1.0 - tc * tc)

    @pl.when(qi < nq)
    def _accumulate():
        dk_scr[...] += jnp.dot(ds.T, qs, preferred_element_type=jnp.float32)
        dv_scr[...] += jnp.dot(p.T, do, preferred_element_type=jnp.float32)

    @pl.when(t == g * nqv - 1)
    def _finalize():
        dk_ref[0, 0] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_scr[...].astype(dv_ref.dtype)


def flash_gqa_bwd_pallas(q, k, v, out, lse, do, window=None, softcap=None,
                         scale=None, bq: int = 512, bk: int = 512,
                         interpret: bool = False, prune_window: bool = True):
    """Fused flash backward.  Residuals: forward output + per-row LSE.

    q/do/out: (B,H,S,D), k/v: (B,KV,S,D), lse: (B,H,S) f32.
    Returns (dq, dk, dv) in the input dtypes.
    """
    b, h, s, d = q.shape
    kv = k.shape[1]
    assert h % kv == 0
    g = h // kv
    sc = scale if scale is not None else d**-0.5

    bq, bk, nq, nk = _block_sizes(s, bq, bk)
    _, nkp = flash_gqa_grid(s, bq, bk, window, prune_window)
    pruned = nkp < nk

    # delta = rowsum(dO * O): O(S*D) elementwise work, done once outside
    # the kernels so both passes read a precomputed (B*H, S) vector.
    delta = jnp.sum(do.astype(jnp.float32) * out.astype(jnp.float32),
                    axis=-1)

    qf = q.reshape(b * h, s, d)
    dof = do.reshape(b * h, s, d)
    lsef = lse.reshape(b * h, s)
    deltaf = delta.reshape(b * h, s)

    # --- dq pass: the forward's grid, accumulating over KV blocks -------
    if pruned:
        def kv_index(bh, qi, j):
            return (bh // h, (bh % h) // g,
                    _first_kv_block(qi, bq, bk, nkp) + j, 0)
    else:
        def kv_index(bh, qi, j):
            return (bh // h, (bh % h) // g, j, 0)

    def q_index(bh, qi, j):
        return (bh, qi, 0)

    def row_index(bh, qi, j):
        return (bh, qi)

    dq = pl.pallas_call(
        functools.partial(
            _flash_bwd_dq_kernel, scale=sc, window=window, softcap=softcap,
            bq=bq, bk=bk, nkp=nkp, pruned=pruned,
        ),
        grid=(b * h, nq, nkp),
        in_specs=[
            pl.BlockSpec((1, bq, d), q_index),
            pl.BlockSpec((1, 1, bk, d), kv_index),
            pl.BlockSpec((1, 1, bk, d), kv_index),
            pl.BlockSpec((1, bq, d), q_index),
            pl.BlockSpec((1, bq), row_index),
            pl.BlockSpec((1, bq), row_index),
        ],
        out_specs=pl.BlockSpec((1, bq, d), q_index),
        out_shape=jax.ShapeDtypeStruct((b * h, s, d), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, d), jnp.float32)],
        interpret=interpret,
    )(qf, k, v, dof, lsef, deltaf)

    # --- dk/dv pass: one pass over K blocks, innermost axis sweeps the ---
    # --- group's query heads x the q-blocks that can see this k-block ----
    _, nqv = flash_gqa_bwd_grid(s, bq, bk, window, prune_window)

    def bwd_q_block(ki, t):
        # clamp: steps past the last q-block load block nq-1; their
        # accumulation is guarded out inside the kernel.
        return jnp.minimum((ki * bk) // bq + t % nqv, nq - 1)

    def bh_index(bkv, t):
        # flattened batch-head for (batch, kv-head, group-member t//nqv)
        return (bkv // kv) * h + (bkv % kv) * g + t // nqv

    def qd_index(bkv, ki, t):
        return (bh_index(bkv, t), bwd_q_block(ki, t), 0)

    def rowd_index(bkv, ki, t):
        return (bh_index(bkv, t), bwd_q_block(ki, t))

    def kvd_index(bkv, ki, t):
        return (bkv // kv, bkv % kv, ki, 0)

    dk, dv = pl.pallas_call(
        functools.partial(
            _flash_bwd_dkv_kernel, scale=sc, window=window, softcap=softcap,
            bq=bq, bk=bk, nq=nq, nqv=nqv, g=g,
        ),
        grid=(b * kv, nk, g * nqv),
        in_specs=[
            pl.BlockSpec((1, bq, d), qd_index),
            pl.BlockSpec((1, 1, bk, d), kvd_index),
            pl.BlockSpec((1, 1, bk, d), kvd_index),
            pl.BlockSpec((1, bq, d), qd_index),
            pl.BlockSpec((1, bq), rowd_index),
            pl.BlockSpec((1, bq), rowd_index),
        ],
        out_specs=(
            pl.BlockSpec((1, 1, bk, d), kvd_index),
            pl.BlockSpec((1, 1, bk, d), kvd_index),
        ),
        out_shape=(
            jax.ShapeDtypeStruct(k.shape, k.dtype),
            jax.ShapeDtypeStruct(v.shape, v.dtype),
        ),
        scratch_shapes=[
            pltpu.VMEM((bk, d), jnp.float32),
            pltpu.VMEM((bk, d), jnp.float32),
        ],
        interpret=interpret,
    )(qf, k, v, dof, lsef, deltaf)

    return dq.reshape(b, h, s, d), dk, dv
