"""Blockwise online-softmax GQA attention - Pallas TPU kernel.

The canonical flash-attention tiling adapted for the assigned archs:

  grid = (B * H, S/BQ, nk)     - the KV block index is the INNERMOST grid
  dimension; TPU executes the grid sequentially per core, so the running
  (m, l, acc) online-softmax state lives in VMEM scratch and persists
  across the KV iterations of one (batch-head, q-block) pair.

  q tile   (BQ, D)  VMEM     k/v tiles (BK, D) VMEM
  scratch: m (BQ,1) l (BQ,1) acc (BQ, D) - all f32.

GQA: query head h reads KV head h // (H/KV) via the k/v BlockSpec index
maps - no KV replication in HBM.  Sliding window + causality are enforced
element-wise inside each tile via broadcasted iota; fully-masked tiles
contribute exp(-inf) = 0.

Window-pruned grid (``prune_window``, default on): with a sliding window
W << S most (q_block, k_block) steps are fully masked, so for windowed
layers the KV grid axis shrinks from nk = S/BK to

  nkp = min(nk, ceil((W + BQ) / BK) + 1)

blocks per q row and the k/v index maps shift to the window: for q block
qi the visited k blocks are max(0, last - nkp + 1) .. last with
last = (qi*BQ + BQ - 1) // BK.  Coverage is exact: every k block holding
a key inside the union of the rows' windows (k in (qi*BQ - W, qi*BQ +
BQ - 1]) lands in that range, earlier blocks are fully outside the
window, and any visited block beyond a row's window is element-masked as
before.  ``flash_gqa_grid`` exposes the resulting (nq, nk_visited) pair -
it is the same computation ``flash_gqa_pallas`` builds its grid from, so
benchmarks/tests assert block-count wins against it directly.

Softcap (gemma2) is applied to the scaled scores before masking, matching
repro/models/attention.py.

D (head_dim) is 64..256 for all assigned archs - lane-aligned; BQ/BK are
multiples of 8 (sublane).  VMEM budget at BQ=BK=512, D=256, f32:
q 512x256x4 = 512 KiB, k+v 1 MiB, acc 512 KiB - comfortably inside 16 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _block_sizes(s: int, bq: int, bk: int):
    """Clamp/halve the requested block sizes until they divide S."""
    bq = min(bq, s)
    while s % bq:
        bq //= 2
    bk = min(bk, s)
    while s % bk:
        bk //= 2
    return bq, bk, s // bq, s // bk


def _first_kv_block(qi, bq: int, bk: int, nkp: int):
    """First visited k-block for q block ``qi`` under the pruned grid.

    The single source for the window shift: both the kernel body's mask
    positions and the k/v BlockSpec index maps derive the true k-block
    index as ``_first_kv_block(qi, ...) + j`` — they MUST agree, or the
    element mask would be computed for a different tile than the one the
    BlockSpec loaded.
    """
    last = (qi * bq + bq - 1) // bk
    return jnp.maximum(last - (nkp - 1), 0)


def flash_gqa_grid(s: int, bq: int = 512, bk: int = 512, window=None,
                   prune_window: bool = True):
    """(nq, nk_visited) for the given sequence/window/tiling.

    ``nk_visited`` is the number of KV grid steps each q row actually
    executes — pruned to ceil((W+BQ)/BK)+1 for sliding-window layers when
    ``prune_window`` (the asymptotic win: O(S·W) instead of O(S²) tiles).
    This is the exact grid ``flash_gqa_pallas`` launches.
    """
    bq, bk, nq, nk = _block_sizes(s, bq, bk)
    if window is None or not prune_window:
        return nq, nk
    return nq, min(nk, pl.cdiv(window + bq, bk) + 1)


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, window, softcap, bq: int, bk: int, nkp: int,
                  pruned: bool):
    qi = pl.program_id(1)
    j = pl.program_id(2)  # pruned: offset into the visited window blocks

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    ki = _first_kv_block(qi, bq, bk, nkp) + j if pruned else j  # true k-block

    q = q_ref[0].astype(jnp.float32) * scale  # (BQ, D)
    k = k_ref[0, 0].astype(jnp.float32)  # (BK, D)
    s = q @ k.T  # (BQ, BK)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = k_pos <= q_pos
    if window is not None:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]  # (BQ, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    # rows with no valid key yet keep m=NEG_INF; clamp so alpha stays finite
    alpha = jnp.exp(jnp.minimum(m_prev - m_new, 0.0))
    p = jnp.exp(s - m_new)  # masked entries: exp(NEG_INF - m) = 0
    l_new = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = alpha * acc_scr[...] + p @ v_ref[0, 0].astype(jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(j == nkp - 1)
    def _finalize():
        l = l_scr[...]
        o_ref[0] = (acc_scr[...] / jnp.where(l == 0.0, 1.0, l)).astype(o_ref.dtype)


def flash_gqa_pallas(q, k, v, window=None, softcap=None, scale=None,
                     bq: int = 512, bk: int = 512, interpret: bool = False,
                     prune_window: bool = True):
    """q: (B,H,S,D), k/v: (B,KV,S,D) -> (B,H,S,D).  Causal GQA."""
    b, h, s, d = q.shape
    kv = k.shape[1]
    assert h % kv == 0
    g = h // kv
    sc = scale if scale is not None else d**-0.5

    bq, bk, nq, nk = _block_sizes(s, bq, bk)
    _, nkp = flash_gqa_grid(s, bq, bk, window, prune_window)
    pruned = nkp < nk

    qf = q.reshape(b * h, s, d)
    grid = (b * h, nq, nkp)

    if pruned:
        # shift the KV grid axis to the window: blocks last-nkp+1 .. last
        def kv_index(bh, qi, j):
            return (bh // h, (bh % h) // g, _first_kv_block(qi, bq, bk, nkp) + j, 0)
    else:
        def kv_index(bh, qi, j):
            return (bh // h, (bh % h) // g, j, 0)

    kernel = functools.partial(
        _flash_kernel, scale=sc, window=window, softcap=softcap,
        bq=bq, bk=bk, nkp=nkp, pruned=pruned,
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, qi, j: (bh, qi, 0)),
            # GQA: map the flattened batch-head index to (batch, kv head)
            pl.BlockSpec((1, 1, bk, d), kv_index),
            pl.BlockSpec((1, 1, bk, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, qi, j: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(qf, k, v)
    return out.reshape(b, h, s, d)
