"""Pallas TPU kernels for the compute hot-spots.

Each subpackage: ``kernel.py`` (pl.pallas_call + explicit BlockSpec VMEM
tiling, TPU target), ``ops.py`` (jit'd public wrapper with an
``interpret=`` switch so CPU CI validates the kernel body), ``ref.py``
(pure-jnp oracle the tests assert against).  ``dispatch.py`` is the
impl-selection layer (auto/reference/kernel/kernel_interpret, DESIGN.md
§9) that wires kernels into the production paths.

  pfedsop_update  fused pFedSOP round-start: 3 dot-product reductions +
                  Gompertz + Sherman-Morrison rescale + parameter AXPY in
                  two HBM sweeps instead of five.  Wired into the
                  federation engines via ``repro.core.pfedsop.personalize``
                  (batched client-axis grid; ``PFedSOPConfig.update_impl``).
  flash_gqa       blockwise online-softmax GQA attention with sliding
                  window + logit softcap (gemma2/3 local-global stacks)
                  and a window-pruned KV grid.  Wired into the model zoo's
                  training/prefill path via
                  ``repro.models.attention.attention_fwd``
                  (``ModelConfig.kernel_impl``).
  rmsnorm         fused mean-square reduction + scale.  Wired into every
                  model-zoo norm via ``repro.models.layers.rmsnorm``
                  (``ModelConfig.kernel_impl``).
"""
