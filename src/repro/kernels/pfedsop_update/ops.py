"""Public wrapper: fused pFedSOP round-start update.

``pfedsop_update(x, delta_i, delta_g, ...)`` takes flat parameter vectors
(any float dtype), pads to (rows, 128) tiles, runs the two-phase kernel and
returns (x_new, beta).  ``pfedsop_update_batched`` is the same update with
a leading participating-client axis — (C, N) operands, (C,) betas — backed
by the (clients, tiles) grid kernels.  ``pfedsop_update_batched_sharded``
is the multi-pod layout (DESIGN.md §11): called inside a mesh-engine
shard_map body, it sweeps only the local model-axis slice of the tile rows
and combines the three Gompertz scalars with a cross-shard psum —
bit-identical to the unsharded batched kernel.  ``pfedsop_update_tree`` is
the pytree convenience for one client.

Call sites: the production path is ``repro.core.pfedsop.personalize``,
which dispatches here when ``PFedSOPConfig.update_impl`` resolves to the
kernel (DESIGN.md §9) — its vmap rule routes the federation engines'
per-client vmap onto ``pfedsop_update_batched``.  Validation lives in
tests/test_kernels.py + tests/test_kernel_dispatch.py (interpret mode) and
``benchmarks/run.py --only pfedsop-update`` times reference vs. kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.pfedsop_update.kernel import (
    _split_rows,
    reduce3_batched_pallas,
    reduce3_pallas,
    update_batched_pallas,
    update_pallas,
)
from repro.kernels.pfedsop_update.ref import gompertz_beta
from repro.utils.pytree import tree_flatten_to_vector, tree_unflatten_from_vector

LANES = 128


def _pad2d(v):
    n = v.shape[0]
    m = -(-n // LANES)  # ceil division -> rows
    pad = m * LANES - n
    return jnp.pad(v, (0, pad)).reshape(m, LANES), n


def _pad3d(v):
    """(C, N) -> (C, M, 128) lane-aligned tiles (zero padding)."""
    c, n = v.shape
    m = -(-n // LANES)
    pad = m * LANES - n
    return jnp.pad(v, ((0, 0), (0, pad))).reshape(c, m, LANES), n


def _coeff_from_sums(dot, nl2, ng2, beta, rho):
    """eta-free Sherman-Morrison coefficient from the three reductions.

    ||dp||^2 expands as a quadratic form of (dot, nl2, ng2) — the fusion
    observation of DESIGN.md §4 — so no fourth sweep is needed."""
    sq = (1.0 - beta) ** 2 * nl2 + 2.0 * beta * (1.0 - beta) * dot + beta**2 * ng2
    return 1.0 / rho - sq / (rho**2 + rho * sq)


@functools.partial(jax.jit, static_argnames=("interpret",))
def pfedsop_update(x, delta_i, delta_g, eta1=0.01, rho=1.0, lam=1.0,
                   eps=1e-12, interpret: bool = False):
    """Flat-vector fused update.  Returns (x_new (N,), beta scalar f32)."""
    di2d, n = _pad2d(delta_i)
    dg2d, _ = _pad2d(delta_g)
    x2d, _ = _pad2d(x)

    partials = reduce3_pallas(di2d, dg2d, interpret=interpret)  # (tiles, 3)
    sums = jnp.sum(partials, axis=0)
    dot, nl2, ng2 = sums[0], sums[1], sums[2]

    beta = gompertz_beta(dot, nl2, ng2, lam, eps)
    coeff = _coeff_from_sums(dot, nl2, ng2, beta, rho)

    out2d = update_pallas(x2d, di2d, dg2d, beta, eta1 * coeff, interpret=interpret)
    return out2d.reshape(-1)[:n], beta


@functools.partial(jax.jit, static_argnames=("interpret",))
def pfedsop_update_batched(x, delta_i, delta_g, eta1=0.01, rho=1.0, lam=1.0,
                           eps=1e-12, interpret: bool = False):
    """Fused update over a leading participating-client axis.

    x/delta_i: (C, N).  delta_g: (C, N), or (N,) for the usual FL case where
    every client sees the same server broadcast — then the kernel reads one
    shared (1, M, 128) buffer instead of materializing C copies.
    Returns (x_new (C, N), beta (C,) f32).
    """
    if delta_g.ndim == 1:
        delta_g = delta_g[None]
    di3d, n = _pad3d(delta_i)
    dg3d, _ = _pad3d(delta_g)
    x3d, _ = _pad3d(x)

    partials = reduce3_batched_pallas(di3d, dg3d, interpret=interpret)
    sums = jnp.sum(partials, axis=1)  # (C, 3)
    dot, nl2, ng2 = sums[:, 0], sums[:, 1], sums[:, 2]

    beta = gompertz_beta(dot, nl2, ng2, lam, eps)  # elementwise -> (C,)
    coeff = _coeff_from_sums(dot, nl2, ng2, beta, rho)

    out3d = update_batched_pallas(x3d, di3d, dg3d, beta, eta1 * coeff,
                                  interpret=interpret)
    return out3d.reshape(x.shape[0], -1)[:, :n], beta


def pfedsop_update_batched_sharded(x, delta_i, delta_g, axis_name: str,
                                   n_shards: int, eta1=0.01, rho=1.0, lam=1.0,
                                   eps=1e-12, interpret: bool = False):
    """Model-sharded batched update: the flattened-N axis over a mesh axis.

    Runs INSIDE a shard_map body whose mesh carries a model-role axis
    ``axis_name`` of size ``n_shards`` (DESIGN.md §11); operands are the
    same replicated (C, N) buffers as ``pfedsop_update_batched``.  Each
    shard sweeps only its contiguous run of tile rows:

      1. slice   — tiles are assigned to shards at the UNSHARDED kernel's
                   tile granularity (``_split_rows(M, 512)`` rows per
                   tile), zero-padding the tile count up to a multiple of
                   ``n_shards``; shard s takes tiles [s*Tl, (s+1)*Tl).
      2. reduce  — the (clients, local tiles) grid kernel emits per-tile
                   partials for the three Gompertz scalars (<d_i,d_g>,
                   ||d_i||^2, ||d_g||^2 — Eqs. 10-13); each shard scatters
                   them into its tile range of a zero (C, T, 3) buffer and
                   a cross-shard **psum** over ``axis_name`` reconstructs
                   the full per-tile partial array exactly (disjoint
                   supports: x + 0.0 is exact).
      3. scalars — beta (Gompertz, Eq. 14) and the Sherman-Morrison
                   coefficient from the summed partials, identically on
                   every shard (replicated scalars).
      4. update  — each shard updates its own tile slice and an all_gather
                   over ``axis_name`` reassembles (C, N).

    Bitwise contract: because the tile decomposition, the per-tile partial
    values and the tile-axis summation order all match the unsharded
    batched kernel, the result is bit-identical to
    ``pfedsop_update_batched`` on the same operands — the anchor of the
    §11 degenerate-parity guarantee (vmap == 1-D shard_map == multi-pod,
    tests/test_multipod.py).  A psum of per-SHARD sums would be cheaper by
    a few bytes but would re-associate the float reduction and break that
    contract.
    """
    lax = jax.lax

    if delta_g.ndim == 1:
        delta_g = delta_g[None]
    di3d, n = _pad3d(delta_i)
    dg3d, _ = _pad3d(delta_g)
    x3d, _ = _pad3d(x)
    c, m, _lanes = x3d.shape

    # tile layout of the UNSHARDED kernel (the bitwise reference); the
    # shared (1, M, 128) broadcast delta slices the same way per shard
    rows = _split_rows(m, 512)
    t = m // rows  # total tiles
    t_loc = -(-t // n_shards)  # tiles per shard (ceil)
    m_pad = t_loc * n_shards * rows
    padrows = lambda a: jnp.pad(a, ((0, 0), (0, m_pad - m), (0, 0)))
    idx = lax.axis_index(axis_name)
    sl = lambda a: lax.dynamic_slice_in_dim(padrows(a), idx * t_loc * rows,
                                            t_loc * rows, axis=1)
    di_l, dg_l, x_l = sl(di3d), sl(dg3d), sl(x3d)

    # per-tile partials on the local tiles, at the reference tile size
    part_l = reduce3_batched_pallas(di_l, dg_l, block_rows=rows,
                                    interpret=interpret)  # (C, t_loc, 3)
    full = jnp.zeros((c, t_loc * n_shards, 3), jnp.float32)
    full = lax.dynamic_update_slice_in_dim(full, part_l, idx * t_loc, axis=1)
    partials = lax.psum(full, axis_name)[:, :t, :]  # exact reconstruction
    sums = jnp.sum(partials, axis=1)  # (C, 3) — same order as unsharded
    dot, nl2, ng2 = sums[:, 0], sums[:, 1], sums[:, 2]

    beta = gompertz_beta(dot, nl2, ng2, lam, eps)  # (C,) — replicated
    coeff = _coeff_from_sums(dot, nl2, ng2, beta, rho)

    out_l = update_batched_pallas(x_l, di_l, dg_l, beta, eta1 * coeff,
                                  block_rows=rows, interpret=interpret)
    out = lax.all_gather(out_l, axis_name, axis=1, tiled=True)  # (C, m_pad, 128)
    return out[:, :m, :].reshape(x.shape[0], -1)[:, :n], beta


def pfedsop_update_tree(params, delta_i, delta_g, eta1=0.01, rho=1.0, lam=1.0,
                        interpret: bool = False):
    """Pytree convenience wrapper for ONE client (flatten -> kernel ->
    unflatten).  The engine-facing batched path lives in
    ``repro.core.pfedsop`` (flatten-once adapter + vmap dispatch)."""
    xv = tree_flatten_to_vector(params)
    div = tree_flatten_to_vector(delta_i)
    dgv = tree_flatten_to_vector(delta_g)
    new_v, beta = pfedsop_update(xv, div, dgv, eta1=eta1, rho=rho, lam=lam,
                                 interpret=interpret)
    return tree_unflatten_from_vector(new_v, params), beta
