"""Public wrapper: fused pFedSOP round-start update.

``pfedsop_update(x, delta_i, delta_g, ...)`` takes flat parameter vectors
(any float dtype), pads to (rows, 128) tiles, runs the two-phase kernel and
returns (x_new, beta).  ``pfedsop_update_tree`` is the pytree convenience
used by launch/steps.py when the kernel path is enabled.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.pfedsop_update.kernel import reduce3_pallas, update_pallas
from repro.kernels.pfedsop_update.ref import gompertz_beta
from repro.utils.pytree import tree_flatten_to_vector, tree_unflatten_from_vector

LANES = 128


def _pad2d(v):
    n = v.shape[0]
    m = -(-n // LANES)  # ceil division -> rows
    pad = m * LANES - n
    return jnp.pad(v, (0, pad)).reshape(m, LANES), n


@functools.partial(jax.jit, static_argnames=("interpret",))
def pfedsop_update(x, delta_i, delta_g, eta1=0.01, rho=1.0, lam=1.0,
                   eps=1e-12, interpret: bool = False):
    """Flat-vector fused update.  Returns (x_new (N,), beta scalar f32)."""
    di2d, n = _pad2d(delta_i)
    dg2d, _ = _pad2d(delta_g)
    x2d, _ = _pad2d(x)

    partials = reduce3_pallas(di2d, dg2d, interpret=interpret)  # (tiles, 3)
    sums = jnp.sum(partials, axis=0)
    dot, nl2, ng2 = sums[0], sums[1], sums[2]

    beta = gompertz_beta(dot, nl2, ng2, lam, eps)
    sq = (1.0 - beta) ** 2 * nl2 + 2.0 * beta * (1.0 - beta) * dot + beta**2 * ng2
    coeff = 1.0 / rho - sq / (rho**2 + rho * sq)

    out2d = update_pallas(x2d, di2d, dg2d, beta, eta1 * coeff, interpret=interpret)
    return out2d.reshape(-1)[:n], beta


def pfedsop_update_tree(params, delta_i, delta_g, eta1=0.01, rho=1.0, lam=1.0,
                        interpret: bool = False):
    """Pytree convenience wrapper (flatten -> kernel -> unflatten)."""
    xv = tree_flatten_to_vector(params)
    div = tree_flatten_to_vector(delta_i)
    dgv = tree_flatten_to_vector(delta_g)
    new_v, beta = pfedsop_update(xv, div, dgv, eta1=eta1, rho=rho, lam=lam,
                                 interpret=interpret)
    return tree_unflatten_from_vector(new_v, params), beta
