"""Public wrapper: fused pFedSOP round-start update.

``pfedsop_update(x, delta_i, delta_g, ...)`` takes flat parameter vectors
(any float dtype), pads to (rows, 128) tiles, runs the two-phase kernel and
returns (x_new, beta).  ``pfedsop_update_batched`` is the same update with
a leading participating-client axis — (C, N) operands, (C,) betas — backed
by the (clients, tiles) grid kernels.  ``pfedsop_update_tree`` is the
pytree convenience for one client.

Call sites: the production path is ``repro.core.pfedsop.personalize``,
which dispatches here when ``PFedSOPConfig.update_impl`` resolves to the
kernel (DESIGN.md §9) — its vmap rule routes the federation engines'
per-client vmap onto ``pfedsop_update_batched``.  Validation lives in
tests/test_kernels.py + tests/test_kernel_dispatch.py (interpret mode) and
``benchmarks/run.py --only pfedsop-update`` times reference vs. kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.pfedsop_update.kernel import (
    reduce3_batched_pallas,
    reduce3_pallas,
    update_batched_pallas,
    update_pallas,
)
from repro.kernels.pfedsop_update.ref import gompertz_beta
from repro.utils.pytree import tree_flatten_to_vector, tree_unflatten_from_vector

LANES = 128


def _pad2d(v):
    n = v.shape[0]
    m = -(-n // LANES)  # ceil division -> rows
    pad = m * LANES - n
    return jnp.pad(v, (0, pad)).reshape(m, LANES), n


def _pad3d(v):
    """(C, N) -> (C, M, 128) lane-aligned tiles (zero padding)."""
    c, n = v.shape
    m = -(-n // LANES)
    pad = m * LANES - n
    return jnp.pad(v, ((0, 0), (0, pad))).reshape(c, m, LANES), n


def _coeff_from_sums(dot, nl2, ng2, beta, rho):
    """eta-free Sherman-Morrison coefficient from the three reductions.

    ||dp||^2 expands as a quadratic form of (dot, nl2, ng2) — the fusion
    observation of DESIGN.md §4 — so no fourth sweep is needed."""
    sq = (1.0 - beta) ** 2 * nl2 + 2.0 * beta * (1.0 - beta) * dot + beta**2 * ng2
    return 1.0 / rho - sq / (rho**2 + rho * sq)


@functools.partial(jax.jit, static_argnames=("interpret",))
def pfedsop_update(x, delta_i, delta_g, eta1=0.01, rho=1.0, lam=1.0,
                   eps=1e-12, interpret: bool = False):
    """Flat-vector fused update.  Returns (x_new (N,), beta scalar f32)."""
    di2d, n = _pad2d(delta_i)
    dg2d, _ = _pad2d(delta_g)
    x2d, _ = _pad2d(x)

    partials = reduce3_pallas(di2d, dg2d, interpret=interpret)  # (tiles, 3)
    sums = jnp.sum(partials, axis=0)
    dot, nl2, ng2 = sums[0], sums[1], sums[2]

    beta = gompertz_beta(dot, nl2, ng2, lam, eps)
    coeff = _coeff_from_sums(dot, nl2, ng2, beta, rho)

    out2d = update_pallas(x2d, di2d, dg2d, beta, eta1 * coeff, interpret=interpret)
    return out2d.reshape(-1)[:n], beta


@functools.partial(jax.jit, static_argnames=("interpret",))
def pfedsop_update_batched(x, delta_i, delta_g, eta1=0.01, rho=1.0, lam=1.0,
                           eps=1e-12, interpret: bool = False):
    """Fused update over a leading participating-client axis.

    x/delta_i: (C, N).  delta_g: (C, N), or (N,) for the usual FL case where
    every client sees the same server broadcast — then the kernel reads one
    shared (1, M, 128) buffer instead of materializing C copies.
    Returns (x_new (C, N), beta (C,) f32).
    """
    if delta_g.ndim == 1:
        delta_g = delta_g[None]
    di3d, n = _pad3d(delta_i)
    dg3d, _ = _pad3d(delta_g)
    x3d, _ = _pad3d(x)

    partials = reduce3_batched_pallas(di3d, dg3d, interpret=interpret)
    sums = jnp.sum(partials, axis=1)  # (C, 3)
    dot, nl2, ng2 = sums[:, 0], sums[:, 1], sums[:, 2]

    beta = gompertz_beta(dot, nl2, ng2, lam, eps)  # elementwise -> (C,)
    coeff = _coeff_from_sums(dot, nl2, ng2, beta, rho)

    out3d = update_batched_pallas(x3d, di3d, dg3d, beta, eta1 * coeff,
                                  interpret=interpret)
    return out3d.reshape(x.shape[0], -1)[:, :n], beta


def pfedsop_update_tree(params, delta_i, delta_g, eta1=0.01, rho=1.0, lam=1.0,
                        interpret: bool = False):
    """Pytree convenience wrapper for ONE client (flatten -> kernel ->
    unflatten).  The engine-facing batched path lives in
    ``repro.core.pfedsop`` (flatten-once adapter + vmap dispatch)."""
    xv = tree_flatten_to_vector(params)
    div = tree_flatten_to_vector(delta_i)
    dgv = tree_flatten_to_vector(delta_g)
    new_v, beta = pfedsop_update(xv, div, dgv, eta1=eta1, rho=rho, lam=lam,
                                 interpret=interpret)
    return tree_unflatten_from_vector(new_v, params), beta
