"""Fused pFedSOP round-start update - Pallas TPU kernels.

The paper's per-round client step (Algorithm 1) is five elementwise/
reduction sweeps over the d-parameter vectors if done naively:

  3 reductions (dot, ||d_i||^2, ||d_g||^2)  ->  beta (Gompertz)
  1 reduction  (||dp||^2)                   ->  Sherman-Morrison coeff
  2 elementwise (dp = lerp, x -= eta*coeff*dp)

Observation (DESIGN.md §4): ||dp||^2 = (1-b)^2||d_i||^2 + 2b(1-b)<d_i,d_g>
+ b^2||d_g||^2 - a quadratic form of the SAME three scalars, so no fourth
sweep is needed.  The kernel pair does:

  phase 1 (reduce):  one pass over (d_i, d_g) tiles accumulating the three
                     dot products in f32; per-tile partials are written out
                     and summed by XLA (tiny).
  phase 2 (update):  one pass computing x - eta*coeff*((1-b) d_i + b d_g)
                     with (beta, eta*coeff) as scalar operands.

=> 2 HBM sweeps instead of 5.  At d ~ 9B params (gemma2-9b) this is the
difference between ~108 GB and ~270 GB of HBM traffic per round start.

Tiles are (ROWS, 128) f32/bf16, lane-aligned; callers pad the flat vector
to a tile multiple (ops.py).

Two grid layouts, same kernel math:

  single-client   grid (tiles,), operands (M, 128) — one flat d-vector.
  batched         grid (clients, tiles), operands (C, M, 128) with the
                  leading participating-client axis; per-client scalars
                  (beta, eta*coeff) ride along as (C, 1) operands.  The
                  server broadcast delta may be shared — shape (1, M, 128)
                  with a client-invariant index map — so the global update
                  is read once, not materialized per client.

The batched layout is what the federation engines dispatch to
(``repro.core.pfedsop`` via ``ops.pfedsop_update_batched``; DESIGN.md §9).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _split_rows(m: int, block_rows: int) -> int:
    """Largest row-block <= block_rows that divides the M tile rows (halving)."""
    rows = min(block_rows, m)
    while m % rows:
        rows //= 2
    return rows


def _reduce_kernel(di_ref, dg_ref, out_ref):
    di = di_ref[...].astype(jnp.float32)
    dg = dg_ref[...].astype(jnp.float32)
    out_ref[0, 0] = jnp.sum(di * dg)
    out_ref[0, 1] = jnp.sum(di * di)
    out_ref[0, 2] = jnp.sum(dg * dg)


def reduce3_pallas(di2d, dg2d, block_rows: int = 512, interpret: bool = False):
    """di2d/dg2d: (M, 128) -> per-tile partials (n_tiles, 3) f32."""
    m, lanes = di2d.shape
    rows = _split_rows(m, block_rows)
    grid = (m // rows,)
    return pl.pallas_call(
        _reduce_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((rows, lanes), lambda i: (i, 0)),
            pl.BlockSpec((rows, lanes), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, 3), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((grid[0], 3), jnp.float32),
        interpret=interpret,
    )(di2d, dg2d)


def _update_kernel(beta_ref, etacoeff_ref, x_ref, di_ref, dg_ref, o_ref):
    beta = beta_ref[0, 0]
    ec = etacoeff_ref[0, 0]
    di = di_ref[...].astype(jnp.float32)
    dg = dg_ref[...].astype(jnp.float32)
    dp = (1.0 - beta) * di + beta * dg
    o_ref[...] = (x_ref[...].astype(jnp.float32) - ec * dp).astype(o_ref.dtype)


def update_pallas(x2d, di2d, dg2d, beta, eta_coeff, block_rows: int = 512,
                  interpret: bool = False):
    """x_new = x - eta_coeff * ((1-beta) d_i + beta d_g), tiled."""
    m, lanes = x2d.shape
    rows = _split_rows(m, block_rows)
    grid = (m // rows,)
    scal = lambda v: jnp.asarray(v, jnp.float32).reshape(1, 1)
    tile = pl.BlockSpec((rows, lanes), lambda i: (i, 0))
    const = pl.BlockSpec((1, 1), lambda i: (0, 0))
    return pl.pallas_call(
        _update_kernel,
        grid=grid,
        in_specs=[const, const, tile, tile, tile],
        out_specs=tile,
        out_shape=jax.ShapeDtypeStruct((m, lanes), x2d.dtype),
        interpret=interpret,
    )(scal(beta), scal(eta_coeff), x2d, di2d, dg2d)


# ---------------------------------------------------------------------------
# Batched (leading participating-client axis) variants
# ---------------------------------------------------------------------------


def _dg_index_map(c_global: int):
    """Client index map for the broadcast delta: shared (C_g=1) operands are
    read from the same block for every client; per-client operands follow
    the grid's client index."""
    if c_global == 1:
        return lambda c, i: (0, i, 0)
    return lambda c, i: (c, i, 0)


def _reduce_batched_kernel(di_ref, dg_ref, out_ref):
    di = di_ref[0].astype(jnp.float32)
    dg = dg_ref[0].astype(jnp.float32)
    out_ref[0, 0, 0] = jnp.sum(di * dg)
    out_ref[0, 0, 1] = jnp.sum(di * di)
    out_ref[0, 0, 2] = jnp.sum(dg * dg)


def reduce3_batched_pallas(di3d, dg3d, block_rows: int = 512,
                           interpret: bool = False):
    """di3d: (C, M, 128); dg3d: (C, M, 128) or (1, M, 128) shared.

    Returns per-(client, tile) partials (C, n_tiles, 3) f32, summed over the
    tile axis by XLA (tiny)."""
    c, m, lanes = di3d.shape
    rows = _split_rows(m, block_rows)
    grid = (c, m // rows)
    return pl.pallas_call(
        _reduce_batched_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, rows, lanes), lambda ci, i: (ci, i, 0)),
            pl.BlockSpec((1, rows, lanes), _dg_index_map(dg3d.shape[0])),
        ],
        out_specs=pl.BlockSpec((1, 1, 3), lambda ci, i: (ci, i, 0)),
        out_shape=jax.ShapeDtypeStruct((c, grid[1], 3), jnp.float32),
        interpret=interpret,
    )(di3d, dg3d)


def _update_batched_kernel(beta_ref, etacoeff_ref, x_ref, di_ref, dg_ref, o_ref):
    beta = beta_ref[0, 0]
    ec = etacoeff_ref[0, 0]
    di = di_ref[0].astype(jnp.float32)
    dg = dg_ref[0].astype(jnp.float32)
    dp = (1.0 - beta) * di + beta * dg
    o_ref[0] = (x_ref[0].astype(jnp.float32) - ec * dp).astype(o_ref.dtype)


def update_batched_pallas(x3d, di3d, dg3d, beta, eta_coeff,
                          block_rows: int = 512, interpret: bool = False):
    """x_new[c] = x[c] - eta_coeff[c] * ((1-beta[c]) d_i[c] + beta[c] d_g[c]).

    x3d/di3d: (C, M, 128); dg3d: (C, M, 128) or (1, M, 128) shared;
    beta/eta_coeff: (C,) f32 per-client scalars."""
    c, m, lanes = x3d.shape
    rows = _split_rows(m, block_rows)
    grid = (c, m // rows)
    scal = lambda v: jnp.asarray(v, jnp.float32).reshape(c, 1)
    tile = lambda f: pl.BlockSpec((1, rows, lanes), f)
    per_client = lambda ci, i: (ci, i, 0)
    const = pl.BlockSpec((1, 1), lambda ci, i: (ci, 0))
    return pl.pallas_call(
        _update_batched_kernel,
        grid=grid,
        in_specs=[const, const, tile(per_client), tile(per_client),
                  tile(_dg_index_map(dg3d.shape[0]))],
        out_specs=tile(per_client),
        out_shape=jax.ShapeDtypeStruct((c, m, lanes), x3d.dtype),
        interpret=interpret,
    )(scal(beta), scal(eta_coeff), x3d, di3d, dg3d)
