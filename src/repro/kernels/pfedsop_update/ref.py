"""Pure-jnp oracle for the fused pFedSOP round-start update (flat vectors).

``pfedsop_update_ref`` is the single-client oracle;
``pfedsop_update_batched_ref`` maps it over a leading client axis for the
batched-kernel parity tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gompertz_beta(dot, nl2, ng2, lam, eps=1e-12):
    denom = jnp.sqrt(nl2) * jnp.sqrt(ng2)
    ok = denom > eps
    sim = jnp.where(ok, dot / jnp.where(ok, denom, 1.0), 0.0)
    sim = jnp.clip(sim, -1.0, 1.0)
    theta = jnp.arccos(sim)
    return 1.0 - jnp.exp(-jnp.exp(-lam * (theta - 1.0)))


def pfedsop_update_ref(x, delta_i, delta_g, eta1, rho, lam, eps=1e-12):
    """Returns (x_new, beta).  x/delta_i/delta_g: (N,) any float dtype.

    Mirrors Algorithm 1: beta from the Gompertz-normalised angle, dp the
    personalized aggregation, Sherman-Morrison rescale, model AXPY.  The
    key identity the kernel exploits: ||dp||^2 is a quadratic form of the
    same three reductions (dot, ||d_i||^2, ||d_g||^2) - no extra sweep.
    """
    di = delta_i.astype(jnp.float32)
    dg = delta_g.astype(jnp.float32)
    dot = jnp.sum(di * dg)
    nl2 = jnp.sum(di * di)
    ng2 = jnp.sum(dg * dg)
    beta = gompertz_beta(dot, nl2, ng2, lam, eps)
    dp = (1.0 - beta) * di + beta * dg
    sq = (1.0 - beta) ** 2 * nl2 + 2.0 * beta * (1.0 - beta) * dot + beta**2 * ng2
    coeff = 1.0 / rho - sq / (rho**2 + rho * sq)
    x_new = (x.astype(jnp.float32) - eta1 * coeff * dp).astype(x.dtype)
    return x_new, beta


def pfedsop_update_batched_ref(x, delta_i, delta_g, eta1, rho, lam, eps=1e-12):
    """Per-client oracle mapped over the leading client axis.

    x/delta_i: (C, N); delta_g: (C, N) or (N,) shared broadcast.
    Returns (x_new (C, N), beta (C,)).
    """
    dg_axis = None if delta_g.ndim == 1 else 0
    return jax.vmap(
        lambda xi, di, dg: pfedsop_update_ref(xi, di, dg, eta1, rho, lam, eps),
        in_axes=(0, 0, dg_axis),
    )(x, delta_i, delta_g)
