"""Kernel-dispatch layer: impl selection for fused Pallas hot paths.

A compute primitive with both a pure-pytree reference implementation and a
fused Pallas kernel is selected by an ``update_impl``-style knob
(DESIGN.md §9).  The contract, shared by every current and future kernel
dispatch (pfedsop_update today; rmsnorm / flash_gqa in the federated LM
path next, ROADMAP "Open items"):

  "auto"              resolve at trace time from the host platform: the
                      Pallas kernel on TPU, the reference path elsewhere.
  "reference"         always the pure-JAX pytree math (the oracle).
  "kernel"            always the Pallas kernel, compiled for the
                      accelerator (Mosaic on TPU).
  "kernel_interpret"  the Pallas kernel body run through the interpreter —
                      same code path and tiling as "kernel" but executable
                      on CPU; used by CI, the parity tests, and the
                      ``benchmarks/run.py --only pfedsop-update
                      --interpret`` smoke bench.

Resolution happens host-side (python, not traced), so the selected impl is
baked into the jitted round function — there is no runtime branch on the
hot path.  The parity guarantee: a kernel impl must match the reference
impl within fp32 reduction-order tolerance on identical inputs (asserted
in tests/test_kernel_dispatch.py).
"""
from __future__ import annotations

import jax

UPDATE_IMPLS = ("auto", "reference", "kernel", "kernel_interpret")


def resolve_update_impl(impl: str) -> str:
    """Resolve an update-impl knob to a concrete impl name.

    Returns one of ("reference", "kernel", "kernel_interpret");
    raises ValueError on anything outside ``UPDATE_IMPLS``.
    """
    if impl not in UPDATE_IMPLS:
        raise ValueError(
            f"unknown update_impl {impl!r}; choose from {UPDATE_IMPLS}"
        )
    if impl == "auto":
        return "kernel" if jax.default_backend() == "tpu" else "reference"
    return impl
