"""Kernel-dispatch layer: impl selection for fused Pallas hot paths.

A compute primitive with both a pure-pytree reference implementation and a
fused Pallas kernel is selected by an impl knob (DESIGN.md §9).  Every
dispatched kernel — ``pfedsop_update`` (knob: ``PFedSOPConfig.update_impl``),
``rmsnorm`` and ``flash_gqa`` (knob: ``ModelConfig.kernel_impl``) — resolves
through the same vocabulary and the same ``resolve_impl`` code path:

  "auto"              resolve at trace time from the host platform: the
                      Pallas kernel on TPU, the reference path elsewhere.
  "reference"         always the pure-JAX math (the oracle).
  "kernel"            always the Pallas kernel, compiled for the
                      accelerator (Mosaic on TPU).
  "kernel_interpret"  the Pallas kernel body run through the interpreter —
                      same code path and tiling as "kernel" but executable
                      on CPU; used by CI, the parity tests, and the
                      interpret-mode benches (``benchmarks/run.py --only
                      pfedsop-update --interpret`` / ``--only model-fwd``).

Resolution happens host-side (python, not traced), so the selected impl is
baked into the jitted round/forward function — there is no runtime branch
on the hot path.  The parity guarantee: a kernel impl must match the
reference impl within fp32 reduction-order tolerance on identical inputs
(asserted in tests/test_kernel_dispatch.py and tests/test_model_dispatch.py).

The per-kernel registry maps each dispatched kernel to the config-knob
name its callers use; registering here is what makes a kernel's "auto"
resolution attributable in logs and its error messages name the right
knob.  New kernel integrations call ``register_kernel`` (or add an entry
below) rather than growing a parallel resolve function.
"""
from __future__ import annotations

import contextlib
import functools
import logging
from typing import Optional, Tuple

import jax

logger = logging.getLogger(__name__)

IMPLS = ("auto", "reference", "kernel", "kernel_interpret")

# Backwards-compatible alias from the first (pfedsop_update-only) dispatch.
UPDATE_IMPLS = IMPLS

# kernel name -> the config-knob name callers select it with (used in error
# messages and the one-shot "auto resolved to ..." log line).
_REGISTRY: dict[str, str] = {}

# kernels whose "auto" resolution has been logged already (log once per
# kernel per process, so long federations don't spam but every run's log
# still says which impl it actually executed).
_AUTO_LOGGED: set[str] = set()


def register_kernel(name: str, knob: str = "kernel_impl") -> None:
    """Register a dispatched kernel under the config knob that selects it."""
    _REGISTRY[name] = knob


def registered_kernels() -> tuple[str, ...]:
    return tuple(_REGISTRY)


@functools.lru_cache(maxsize=1)
def _default_backend() -> str:
    """The host platform, looked up once per process.

    ``jax.default_backend()`` initializes the backend on first call; hoisting
    it behind a cache keeps repeated resolution (every norm/attention call
    site of every layer trace) off that path.
    """
    return jax.default_backend()


def resolve_impl(impl: str, kernel: str) -> str:
    """Resolve an impl knob for a registered kernel to a concrete impl name.

    Returns one of ("reference", "kernel", "kernel_interpret"); raises
    ValueError on an unregistered kernel or anything outside ``IMPLS``.
    """
    knob = _REGISTRY.get(kernel)
    if knob is None:
        raise ValueError(
            f"unregistered kernel {kernel!r}; registered: {registered_kernels()}"
        )
    if impl not in IMPLS:
        raise ValueError(f"unknown {knob} {impl!r}; choose from {IMPLS}")
    if impl != "auto":
        return impl
    backend = _default_backend()
    resolved = "kernel" if backend == "tpu" else "reference"
    if kernel not in _AUTO_LOGGED:
        _AUTO_LOGGED.add(kernel)
        # routed through the obs structured logger: the stdlib record keeps
        # its historical logger name + format (pinned by the dispatch tests),
        # and an open trace additionally gets a structured mirror record
        from repro.obs import get_obs

        get_obs().log.info(
            f"kernel-dispatch: {knob}=auto resolved to {resolved!r} for "
            f"{kernel} (backend={backend})",
            logger=logger, event="kernel_dispatch",
            kernel=kernel, knob=knob, impl=resolved, backend=backend,
        )
    return resolved


# ---------------------------------------------------------------------------
# Mesh-axis contexts (DESIGN.md §11)
#
# When a mesh-aware federation engine traces a phase inside a shard_map,
# code that supports a sharded layout should split its work over the
# announced mesh axis instead of running replicated on every shard.  The
# engine announces the axis with a context manager around body tracing;
# consumers read the ``current_*`` getter host-side, so the choice is
# baked into the trace like every other dispatch decision.  Three roles:
#
#   model_shard_axis   kernels with a model-sharded layout (pfedsop_
#                      update's flattened-N axis) split their sweep —
#                      per-shard partials + cross-shard psum.
#   client_shard_axis  the sharded aggregation program (§11 output-
#                      sharding): cohort reductions (``repro.optim.
#                      reduce.cohort_mean``/``cohort_sum``) combine
#                      shard-local halving-tree partials in shard order.
#   data_shard_axis    the per-client batch is sharded over the data
#                      axis: ``optim.sgd.chunked_value_and_grad`` treats
#                      the local slice as its gradient chunk and gathers
#                      the chunk partials across the axis.
# ---------------------------------------------------------------------------


def _axis_context(stack: list):
    @contextlib.contextmanager
    def ctx(axis_name: str, n_shards: int):
        stack.append((axis_name, int(n_shards)))
        try:
            yield
        finally:
            stack.pop()

    def current() -> Optional[Tuple[str, int]]:
        return stack[-1] if stack else None

    return ctx, current


_MODEL_SHARD_STACK: list = []
_CLIENT_SHARD_STACK: list = []
_DATA_SHARD_STACK: list = []

model_shard_axis, current_model_shard = _axis_context(_MODEL_SHARD_STACK)
client_shard_axis, current_client_shard = _axis_context(_CLIENT_SHARD_STACK)
data_shard_axis, current_data_shard = _axis_context(_DATA_SHARD_STACK)

model_shard_axis.__name__ = "model_shard_axis"
client_shard_axis.__name__ = "client_shard_axis"
data_shard_axis.__name__ = "data_shard_axis"


# ---------------------------------------------------------------------------
# Gradient-chunk context (DESIGN.md §11)
#
# ``FLRunConfig.grad_chunks`` fixes the *numeric semantics* of each local
# SGD step: the gradient is the canonical chunk-tree reduction over n
# equal batch chunks (``repro.optim.reduce``), whether those chunks are
# computed in-body (data axis inactive) or one-per-device over the data
# axis.  The run driver enters this context around every call of the
# jitted client program — jit defers tracing to the first call, so the
# count is read at trace time, like the mesh-axis contexts above.
# ---------------------------------------------------------------------------

_GRAD_CHUNK_STACK: list = []


@contextlib.contextmanager
def grad_chunk_count(n: int):
    """Declare the run-level gradient chunk count around client tracing."""
    _GRAD_CHUNK_STACK.append(int(n))
    try:
        yield
    finally:
        _GRAD_CHUNK_STACK.pop()


def current_grad_chunks() -> int:
    """The active gradient chunk count (1 outside any context)."""
    return _GRAD_CHUNK_STACK[-1] if _GRAD_CHUNK_STACK else 1


@contextlib.contextmanager
def kernel_scope(kernel: str, impl: str):
    """Name a dispatched-kernel launch in profiles (DESIGN.md §13).

    Always wraps tracing in ``jax.named_scope`` so the resolved impl shows
    up in HLO op names / XLA profiles for free; at ``kernel`` obs level it
    additionally opens a ``jax.profiler.TraceAnnotation`` so the launch is
    attributable in a ``--xla-profile`` capture.  Host-side only — the
    traced computation is unchanged (names, not values).
    """
    from repro.obs import LEVEL_KERNEL, get_obs

    label = f"{kernel}[{impl}]"
    with contextlib.ExitStack() as stack:
        stack.enter_context(jax.named_scope(label))
        if get_obs().level >= LEVEL_KERNEL:
            stack.enter_context(jax.profiler.TraceAnnotation(label))
        yield


def resolve_update_impl(impl: str) -> str:
    """Resolve the pFedSOP round-start-update knob (back-compat wrapper).

    Returns one of ("reference", "kernel", "kernel_interpret");
    raises ValueError on anything outside ``UPDATE_IMPLS``.
    """
    return resolve_impl(impl, "pfedsop_update")


register_kernel("pfedsop_update", knob="update_impl")
register_kernel("rmsnorm")
register_kernel("flash_gqa")
# The attention backward dispatches independently of the forward: "reference"
# is the blockwise scan-of-VJPs (oracle math), the kernel impls run the
# fused two-pass flash backward (kernel.flash_gqa_bwd_pallas).
register_kernel("flash_gqa_bwd")
