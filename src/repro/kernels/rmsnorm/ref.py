"""Pure-jnp oracle for the rmsnorm kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rmsnorm_ref(x, scale, eps: float = 1e-6):
    """x: (..., D); scale: (D,).  (1+scale) parametrisation, f32 reduce."""
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)
