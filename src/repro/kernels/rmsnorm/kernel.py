"""Fused RMSNorm Pallas TPU kernel.

Tiling: grid over row blocks; each program normalises a (ROWS, D) VMEM tile
(rows = tokens, D = model dim).  The mean-square reduction and the scale
multiply happen in one VMEM pass - one HBM read + one HBM write per
element, vs read(reduce) + read(scale) for the unfused pair.

ROWS is sized so the tile fits comfortably in VMEM: ROWS*D*4B (f32 compute
copy) <= ~4 MiB leaves headroom for the bf16 input/output tiles.  D is the
lane-aligned model dim (all assigned archs have D % 128 == 0).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, scale_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)  # (ROWS, D)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * (1.0 + scale_ref[...].astype(jnp.float32))).astype(o_ref.dtype)


def rmsnorm_pallas(x, scale, eps: float = 1e-6, block_rows: int = 256,
                   interpret: bool = False):
    """x: (N, D) (callers flatten leading dims); scale: (D,)."""
    n, d = x.shape
    rows = min(block_rows, n)
    while n % rows:
        rows //= 2
    rows = max(rows, 1)
    grid = (n // rows,)
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), x.dtype),
        interpret=interpret,
    )(x, scale)
