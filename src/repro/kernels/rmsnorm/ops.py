"""Public jit'd wrapper for the rmsnorm kernel (arbitrary leading dims).

Call sites: the model zoo — ``repro.models.layers.rmsnorm`` dispatches
here for every transformer/MoE/SSM-hybrid norm (ln1/ln2/final_norm and the
qk-norm) when ``ModelConfig.kernel_impl`` resolves to a kernel impl
(DESIGN.md §9) — plus tests/test_kernels.py, tests/test_model_dispatch.py
and ``benchmarks/run.py --only kernels / model-fwd``.

Differentiable: the forward pass runs the fused Pallas kernel; the
backward pass is the VJP of the jnp oracle (``ref.py``) on the saved
inputs — same math, reference reduction order.  A fused backward kernel
is a future perf item; under ``remat="block"`` the recomputed forward
stays on the kernel path either way.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels.rmsnorm.kernel import rmsnorm_pallas
from repro.kernels.rmsnorm.ref import rmsnorm_ref


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def _rmsnorm(x, scale, eps, interpret):
    lead = x.shape[:-1]
    d = x.shape[-1]
    flat = x.reshape(-1, d)
    out = rmsnorm_pallas(flat, scale, eps=eps, interpret=interpret)
    return out.reshape(*lead, d)


def _rmsnorm_fwd(x, scale, eps, interpret):
    return _rmsnorm(x, scale, eps, interpret), (x, scale)


def _rmsnorm_bwd(eps, interpret, res, g):
    x, scale = res
    _, vjp = jax.vjp(lambda xx, ss: rmsnorm_ref(xx, ss, eps), x, scale)
    return vjp(g)


_rmsnorm.defvjp(_rmsnorm_fwd, _rmsnorm_bwd)


@functools.partial(jax.jit, static_argnames=("eps", "interpret"))
def rmsnorm(x, scale, eps: float = 1e-6, interpret: bool = False):
    return _rmsnorm(x, scale, eps, interpret)
