"""Public jit'd wrapper for the rmsnorm kernel (arbitrary leading dims).

Call sites: tests/test_kernels.py and ``benchmarks/run.py --only kernels``
only — the model zoo (``repro.models.layers.rmsnorm``) still runs the
plain-jnp norm (mirrored by ref.py).  Routing the transformer stacks
through the DESIGN.md §9 dispatch layer is a ROADMAP open item.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels.rmsnorm.kernel import rmsnorm_pallas


@functools.partial(jax.jit, static_argnames=("eps", "interpret"))
def rmsnorm(x, scale, eps: float = 1e-6, interpret: bool = False):
    lead = x.shape[:-1]
    d = x.shape[-1]
    flat = x.reshape(-1, d)
    out = rmsnorm_pallas(flat, scale, eps=eps, interpret=interpret)
    return out.reshape(*lead, d)
