"""Public jit'd wrapper for the rmsnorm kernel (arbitrary leading dims)."""
from __future__ import annotations

import functools

import jax

from repro.kernels.rmsnorm.kernel import rmsnorm_pallas


@functools.partial(jax.jit, static_argnames=("eps", "interpret"))
def rmsnorm(x, scale, eps: float = 1e-6, interpret: bool = False):
    lead = x.shape[:-1]
    d = x.shape[-1]
    flat = x.reshape(-1, d)
    out = rmsnorm_pallas(flat, scale, eps=eps, interpret=interpret)
    return out.reshape(*lead, d)
