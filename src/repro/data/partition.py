"""The paper's two heterogeneous partitioners (Sec. V-A).

``dirichlet_partition``   - FedDWA-style: for each class, the class's samples
                            are split across the K clients with proportions
                            drawn from Dir(alpha); alpha=0.07 in the paper.
``pathological_partition``- FedALA-style shard partitioner: samples sorted by
                            label are cut into s shards of size z; each
                            client receives b = s/K shards, so it sees ~b
                            classes (z=200/600/1000 for CIFAR10/100/Tiny).

Both return a list of K index arrays into the input label vector.
"""
from __future__ import annotations

import numpy as np


def dirichlet_partition(labels: np.ndarray, n_clients: int, alpha: float, seed: int = 0):
    rng = np.random.RandomState(seed)
    n_classes = int(labels.max()) + 1
    client_idx = [[] for _ in range(n_clients)]
    for c in range(n_classes):
        idx = np.where(labels == c)[0]
        rng.shuffle(idx)
        props = rng.dirichlet(np.full(n_clients, alpha))
        # split points from cumulative proportions
        cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
        for i, part in enumerate(np.split(idx, cuts)):
            client_idx[i].extend(part.tolist())
    out = []
    for i in range(n_clients):
        arr = np.asarray(client_idx[i], np.int64)
        rng.shuffle(arr)
        out.append(arr)
    return out


def pathological_partition(labels: np.ndarray, n_clients: int, shard_size: int, seed: int = 0):
    """Sort-by-label -> shards of ``shard_size`` -> b shards per client."""
    rng = np.random.RandomState(seed)
    n = len(labels)
    order = np.argsort(labels, kind="stable")
    n_shards = n // shard_size
    usable = n_shards * shard_size
    shards = order[:usable].reshape(n_shards, shard_size)
    perm = rng.permutation(n_shards)
    b = n_shards // n_clients
    assert b >= 1, f"need >= {n_clients} shards, got {n_shards}"
    out = []
    for i in range(n_clients):
        take = perm[i * b : (i + 1) * b]
        idx = shards[take].reshape(-1).copy()
        rng.shuffle(idx)
        out.append(idx.astype(np.int64))
    return out
