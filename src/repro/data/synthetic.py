"""Synthetic class-conditional image generator.

CIFAR/TinyImageNet are not available offline, so the paper-faithful
experiments run on a synthetic dataset with the same *shape* and the same
partition statistics: each class c gets a random smooth template
(low-frequency mixture) and samples are template + per-sample noise +
random shift.  The classification task is learnable but not trivial - a
small CNN separates classes in a few epochs, which is exactly what the FL
convergence comparison needs (the paper's claims are about *relative*
convergence speed across FL methods, not absolute CIFAR accuracy; see
DESIGN.md §1 band realism).
"""
from __future__ import annotations

import numpy as np


def make_class_conditional_images(
    n_samples: int,
    n_classes: int,
    image_size: int = 32,
    channels: int = 3,
    noise: float = 0.35,
    seed: int = 0,
):
    """Returns (images (N,H,W,C) f32 in [-1,1]-ish, labels (N,) int32).

    Samples are balanced across classes (n_samples // n_classes each, the
    remainder distributed to the first classes) mirroring CIFAR's balance.
    """
    rng = np.random.RandomState(seed)
    h = w = image_size

    # low-frequency class templates: sum of a few random 2-D cosines
    yy, xx = np.meshgrid(np.arange(h), np.arange(w), indexing="ij")
    templates = np.zeros((n_classes, h, w, channels), np.float32)
    for c in range(n_classes):
        for _ in range(4):
            fy, fx = rng.uniform(0.5, 3.0, 2)
            phase = rng.uniform(0, 2 * np.pi, 2)
            amp = rng.uniform(0.4, 1.0)
            ch_w = rng.uniform(-1, 1, channels)
            base = amp * np.cos(2 * np.pi * fy * yy / h + phase[0]) * np.cos(
                2 * np.pi * fx * xx / w + phase[1]
            )
            templates[c] += base[:, :, None] * ch_w[None, None, :]
    templates /= np.abs(templates).max(axis=(1, 2, 3), keepdims=True) + 1e-6

    counts = np.full(n_classes, n_samples // n_classes)
    counts[: n_samples % n_classes] += 1
    labels = np.repeat(np.arange(n_classes), counts).astype(np.int32)
    rng.shuffle(labels)

    images = np.empty((n_samples, h, w, channels), np.float32)
    for i, c in enumerate(labels):
        sy, sx = rng.randint(-2, 3, 2)
        t = np.roll(np.roll(templates[c], sy, axis=0), sx, axis=1)
        images[i] = t + noise * rng.randn(h, w, channels)
    return images, labels
