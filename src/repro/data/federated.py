"""Federated dataset container for the vmap'd simulation backend.

Stores the full sample bank once (images/labels) plus per-client index
tables (padded to the max client size, with counts).  Per round it samples
local SGD batches *with replacement* inside each client's own training
indices - this is the one documented deviation from per-epoch sequential
batching (DESIGN.md §8): every client runs the same number T of local
iterations so the federation vmaps/scans as a single SPMD program.  With
T = ceil(mean_n / batch) the expected sample usage matches the paper's
"one local epoch".
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List

import numpy as np


@dataclass
class FederatedData:
    images: np.ndarray  # (N, H, W, C) f32 - the global sample bank
    labels: np.ndarray  # (N,) int32
    train_idx: np.ndarray  # (K, max_train) int64, padded with repeats
    train_counts: np.ndarray  # (K,) int64
    test_idx: np.ndarray  # (K, max_test) int64
    test_counts: np.ndarray  # (K,) int64

    @property
    def n_clients(self) -> int:
        return self.train_idx.shape[0]

    @classmethod
    def from_partition(
        cls,
        images: np.ndarray,
        labels: np.ndarray,
        client_indices: List[np.ndarray],
        train_frac: float = 0.8,
        seed: int = 0,
    ) -> "FederatedData":
        """80/20 per-client train/test split (paper Sec. V-A)."""
        rng = np.random.RandomState(seed)
        tr, te, ntr, nte = [], [], [], []
        for idx in client_indices:
            idx = np.asarray(idx, np.int64)
            rng.shuffle(idx)
            k = max(1, int(round(train_frac * len(idx)))) if len(idx) else 0
            tr.append(idx[:k])
            te.append(idx[k:] if len(idx) - k > 0 else idx[:1])  # >=1 test sample
            ntr.append(len(tr[-1]))
            nte.append(len(te[-1]))

        def pad(rows):
            m = max(1, max(len(r) for r in rows))
            out = np.zeros((len(rows), m), np.int64)
            for i, r in enumerate(rows):
                if len(r) == 0:
                    continue
                reps = int(np.ceil(m / len(r)))
                out[i] = np.tile(r, reps)[:m]
            return out

        return cls(
            images=np.asarray(images, np.float32),
            labels=np.asarray(labels, np.int32),
            train_idx=pad(tr),
            train_counts=np.asarray(ntr, np.int64),
            test_idx=pad(te),
            test_counts=np.asarray(nte, np.int64),
        )

    # -- per-round sampling ------------------------------------------------

    def local_iters(self, batch: int) -> int:
        """T for 'one local epoch' semantics at the mean client size."""
        mean_n = max(1.0, float(self.train_counts.mean()))
        return max(1, int(np.ceil(mean_n / batch)))

    def sample_round_batches(self, rng: np.random.RandomState, client_ids, T: int, batch: int):
        """Returns {"images": (K',T,B,H,W,C), "labels": (K',T,B)}."""
        client_ids = np.asarray(client_ids)
        kprime = len(client_ids)
        slots = rng.randint(
            0,
            np.maximum(1, self.train_counts[client_ids])[:, None, None],
            size=(kprime, T, batch),
        )
        gidx = self.train_idx[client_ids][np.arange(kprime)[:, None, None], slots]
        return {"images": self.images[gidx], "labels": self.labels[gidx]}

    def client_test_set(self, client_ids):
        """Padded per-client test sets + masks.

        Returns {"images": (K',M,H,W,C), "labels": (K',M), "mask": (K',M)}.
        """
        client_ids = np.asarray(client_ids)
        gidx = self.test_idx[client_ids]
        m = gidx.shape[1]
        mask = np.arange(m)[None, :] < self.test_counts[client_ids][:, None]
        return {
            "images": self.images[gidx],
            "labels": self.labels[gidx],
            "mask": mask.astype(np.float32),
        }
