"""Federated data substrate: synthetic generators + the paper's partitioners."""
from repro.data.synthetic import make_class_conditional_images  # noqa: F401
from repro.data.partition import dirichlet_partition, pathological_partition  # noqa: F401
from repro.data.federated import FederatedData  # noqa: F401
from repro.data.lm import synthetic_lm_stream, lm_batch_iterator  # noqa: F401
