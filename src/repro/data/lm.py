"""Synthetic LM token streams for the assigned-architecture examples/smokes.

A tiny order-2 Markov chain over the vocabulary gives the stream enough
structure that a decoder's loss visibly drops within a few hundred steps
(the end-to-end ~100M-model training driver in examples/train_lm.py needs a
learnable signal, not uniform noise).
"""
from __future__ import annotations

import numpy as np


def synthetic_lm_stream(n_tokens: int, vocab_size: int, seed: int = 0,
                        branch: int = 4) -> np.ndarray:
    """Markov stream: each (prev token) allows only ``branch`` successors."""
    rng = np.random.RandomState(seed)
    succ = rng.randint(0, vocab_size, size=(vocab_size, branch))
    out = np.empty(n_tokens, np.int32)
    t = rng.randint(vocab_size)
    for i in range(n_tokens):
        out[i] = t
        t = succ[t, rng.randint(branch)]
    return out


def lm_batch_iterator(stream: np.ndarray, batch: int, seq_len: int, seed: int = 0):
    """Yields {"tokens": (B,S), "labels": (B,S)} forever (next-token shift)."""
    rng = np.random.RandomState(seed)
    n = len(stream) - seq_len - 1
    assert n > 0, "stream too short"
    while True:
        starts = rng.randint(0, n, size=batch)
        toks = np.stack([stream[s : s + seq_len] for s in starts])
        labs = np.stack([stream[s + 1 : s + seq_len + 1] for s in starts])
        yield {"tokens": toks, "labels": labs}
