"""FL runtime: backend-pluggable federation engine (vmap / shard_map).

``Federation`` drives the round loop; the engine backend (DESIGN.md §3)
decides where the per-client phase runs.  See README.md for the repo map.
"""
from repro.fl.engine import (  # noqa: F401
    BACKENDS,
    FederationEngine,
    ShardMapBackend,
    VmapBackend,
    make_engine,
    resolve_shards,
)
from repro.fl.runtime import (  # noqa: F401
    Federation,
    FLRunConfig,
    override_update_impl,
    validate_method,
)
