"""FL runtime: vmap'd single-host simulation + distributed round logic."""
from repro.fl.runtime import Federation, FLRunConfig  # noqa: F401
