"""FL runtime: backend-pluggable federation engine (vmap / shard_map / mesh).

``Federation`` drives the synchronous round loop; ``AsyncFederation``
(DESIGN.md §10) replaces it with an availability-aware discrete-event
simulation with FedBuff-style staleness-weighted buffered aggregation.
Both share the jitted phase programs in ``repro.fl.runtime.RoundPrograms``
and the engine backends (DESIGN.md §3; the multi-pod ``MeshBackend`` and
its role-named mesh layer are DESIGN.md §11).  Per-client personalized
state lives in a ``repro.fl.cohort_store.CohortStore`` (DESIGN.md §12):
at rest on device, host RAM, or disk-backed memmap, gathered to device
only for a round's participants — fleet size is a throughput knob, not a
device-memory limit.  See README.md for the repo map.
"""
from repro.fl.async_ import AsyncConfig, AsyncFederation  # noqa: F401
from repro.fl.availability import (  # noqa: F401
    AvailabilityConfig,
    ClientAvailability,
    TraceAvailability,
    TraceAvailabilityConfig,
    make_availability,
)
from repro.fl.cohort_store import (  # noqa: F401
    STORE_KINDS,
    CohortStore,
    DeviceStore,
    HostStore,
    StoreConfig,
    as_store_config,
    make_store,
)
from repro.fl.engine import (  # noqa: F401
    BACKENDS,
    FederationEngine,
    MeshBackend,
    ShardMapBackend,
    VmapBackend,
    make_engine,
    resolve_client_split,
    resolve_shards,
)
from repro.fl.runtime import (  # noqa: F401
    Federation,
    FLRunConfig,
    RoundPrograms,
    override_update_impl,
    validate_method,
)
from repro.fl.scheduler import RoundScheduler  # noqa: F401
