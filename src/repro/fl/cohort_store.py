"""Fleet-scale cohort store: per-client state at rest on host (DESIGN.md §12).

The federation keeps every client's personalized state as one stacked
pytree with a leading K axis (DESIGN.md §3).  Resident on device that
layout caps K at accelerator memory — but pFedSOP's partial participation
means each round touches only K' << K clients, so the store moves the
stack *at rest* to host numpy (optionally memory-mapped to disk past a
size threshold) and materializes only the round's participants on device:

    gather(ids)  host rows -> device (K', ...) cohort   [h2d]
    scatter(ids) device (K', ...) cohort -> host rows   [d2h, async]

K becomes a throughput knob instead of a memory limit.  Three stores
behind one interface, selected by ``StoreConfig.kind``:

  DeviceStore  the seed behaviour: stacked jnp tree resident on device,
               gather/scatter are the jitted take/at[ids].set programs the
               runtime previously owned.  kind="device".
  HostStore    stacked numpy at rest (kind="host"), or numpy memmaps under
               ``mmap_dir`` (kind="mmap"; a "host" store auto-promotes to
               mmap when its at-rest bytes exceed ``mmap_threshold_bytes``).
               Gather batches the participants' rows through ONE
               ``jax.device_put`` per leaf — against the engine's input
               shardings when provided, so a multi-pod mesh receives
               per-pod slices directly (DESIGN.md §11) instead of a full
               replicated cohort.  Scatter starts ``copy_to_host_async``
               on every leaf and *defers* the numpy write-back until the
               next host access (gather/stacked/save), overlapping the
               d2h copies with the host-side sampling + dispatch of the
               next round — the §12 overlap timeline.

An optional LRU device cache (``cache_clients > 0``) keeps the most
recently touched clients' device rows resident, skipping the h2d copy for
frequently-sampled clients (hit/miss/eviction counts in ``stats()``).
The cache serves the default single-device placement only: a sharded
gather (mesh/shard_map input shardings) bypasses it, because per-pod
placement of individual cached rows would re-shard what the bypass path
lays out directly.

Bitwise contract (asserted in tests/test_cohort_store.py across
{vmap, shard_map, mesh} x {sync, async}): gather and scatter are pure
data movement — np<->jnp round-trips are bit-exact and the jitted phase
programs receive identical operand *values* regardless of store kind —
so a streamed federation reproduces the all-on-device history bitwise.

Checkpointing streams the store beside the driver's arrays.npz in
client-range shards (``store_00000.npz`` + ``store_manifest.json`` under
the same ``step_<N>/`` directory), bounding checkpoint working memory at
``ckpt_shard_clients`` rows regardless of K.
"""
from __future__ import annotations

import json
import tempfile
from collections import OrderedDict
from dataclasses import asdict, dataclass
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.utils.checkpoint import _flatten_with_names

Pytree = Any

STORE_KINDS = ("device", "host", "mmap")


@dataclass(frozen=True)
class StoreConfig:
    """Where the K-stacked client states live at rest (DESIGN.md §12).

    ``kind``: "device" (seed behaviour, resident jnp stack), "host"
    (numpy at rest, auto-promoting to memmap past ``mmap_threshold_bytes``)
    or "mmap" (always disk-backed memmaps under ``mmap_dir``).

    ``cache_clients``: LRU device cache capacity in clients (0 = off);
    host/mmap stores only — the device store is its own cache.

    ``mmap_dir``: backing directory for memmapped leaves ("" = a fresh
    ``tempfile.mkdtemp``; checkpoints never depend on it — shards are
    written under the checkpoint step directory).

    ``mmap_threshold_bytes``: a "host" store spills to memmaps when the
    at-rest stack exceeds this many bytes (0 = never spill).

    ``ckpt_shard_clients``: clients per checkpoint shard file — the
    checkpoint path's working-memory bound.
    """

    kind: str = "device"
    cache_clients: int = 0
    mmap_dir: str = ""
    mmap_threshold_bytes: int = 4 << 30  # 4 GiB
    ckpt_shard_clients: int = 65536

    def __post_init__(self):
        if self.kind not in STORE_KINDS:
            raise ValueError(
                f"store kind must be one of {STORE_KINDS}, got {self.kind!r}"
            )
        if self.cache_clients < 0:
            raise ValueError(
                f"cache_clients must be >= 0, got {self.cache_clients}"
            )
        if self.cache_clients and self.kind == "device":
            raise ValueError(
                "cache_clients only applies to host/mmap stores (the device "
                "store is already resident); drop the flag or pick "
                "store='host'"
            )
        if self.ckpt_shard_clients < 1:
            raise ValueError(
                f"ckpt_shard_clients must be >= 1, got {self.ckpt_shard_clients}"
            )


def as_store_config(store) -> StoreConfig:
    """Resolve ``FLRunConfig.store``: None -> device, str -> kind, or a
    full ``StoreConfig`` passed through."""
    if store is None:
        return StoreConfig()
    if isinstance(store, str):
        return StoreConfig(kind=store)
    if isinstance(store, StoreConfig):
        return store
    raise TypeError(
        f"store must be None, a kind string {STORE_KINDS}, or a StoreConfig; "
        f"got {type(store).__name__}"
    )


def _tree_bytes(tree) -> int:
    return sum(leaf.nbytes for leaf in jax.tree.leaves(tree))


class CohortStore:
    """Interface + shared bookkeeping of the two store implementations.

    ``proto`` is ONE client's state pytree; the store broadcasts it to the
    (K,)-stacked at-rest layout (every client starts from the same
    initialization — paper Sec. V-B4).  Stats keys are the §12 bench
    columns: gathers/scatters, h2d/d2h bytes actually moved, and the LRU
    cache's hit/miss/eviction counters.
    """

    def __init__(self, cfg: StoreConfig, k: int):
        self.cfg = cfg
        self.k = k
        self._stats = {
            "gathers": 0, "scatters": 0, "h2d_bytes": 0, "d2h_bytes": 0,
            "cache_hits": 0, "cache_misses": 0, "cache_evictions": 0,
            # batched-cache counters (DESIGN.md §13): cohorts assembled by
            # the slot buffer's single gather-by-index, and rows written
            # into it by batched inserts (gather misses + scatter
            # write-through) — one device op each where the pre-batched
            # cache issued one per row
            "cache_assembles": 0, "cache_insert_rows": 0,
        }

    # -- the gather/scatter contract (DESIGN.md §12) ----------------------

    def gather(self, ids: np.ndarray, shardings=None) -> Pytree:
        """Stacked (K', ...) device cohort for ``ids`` (row order = ids
        order).  ``shardings``: optional tree of ``NamedSharding`` (one
        per leaf, from ``FederationEngine.input_shardings``) the cohort is
        placed against — the mesh backends' per-pod gather."""
        raise NotImplementedError

    def scatter(self, ids: np.ndarray, new_states: Pytree) -> None:
        """Write the (K', ...) cohort back to rows ``ids``."""
        raise NotImplementedError

    def offload(self, tree: Pytree, force_host: bool = False) -> Pytree:
        """Representation for results buffered OUTSIDE the store (the
        async driver's in-flight dispatches): host copies whenever the
        store itself is host-resident — buffered uploads must never pin
        device memory — or when the caller forces it (the sharded-backend
        mesh-lifetime rule in ``AsyncFederation._dispatch``)."""
        raise NotImplementedError

    # -- whole-stack access (checkpoints, tests, property access) ---------

    def stacked(self) -> Pytree:
        """The full (K, ...) stacked tree in the at-rest representation."""
        raise NotImplementedError

    def load_stacked(self, tree: Pytree) -> None:
        """Replace the full stack (values copied into the at-rest layout)."""
        raise NotImplementedError

    def stacked_struct(self) -> Pytree:
        """ShapeDtypeStruct tree of the stacked layout (pspec probes)."""
        return jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(jnp.shape(x), x.dtype),
            self.stacked(),
        )

    # -- stats / fingerprint ----------------------------------------------

    def stats(self) -> dict:
        return dict(self._stats)

    def describe(self) -> dict:
        """Store facets stamped into the checkpoint fingerprint
        (repro.fl.runtime._run_fingerprint): the at-rest layout a resumed
        driver must share to restore the step directory's shard files."""
        return {"kind": self.cfg.kind, "cache_clients": self.cfg.cache_clients}

    # -- checkpoint shard streaming (DESIGN.md §12) -----------------------

    def _shard_ranges(self):
        s = self.cfg.ckpt_shard_clients
        return [(lo, min(lo + s, self.k)) for lo in range(0, max(self.k, 1), s)]

    def save_shards(self, step_dir) -> None:
        """Stream the stack into ``<step_dir>/store_<i>.npz`` client-range
        shards + a ``store_manifest.json`` naming the flattened leaves —
        working memory is bounded by one shard, not K."""
        d = Path(step_dir)
        d.mkdir(parents=True, exist_ok=True)
        ranges = self._shard_ranges()
        names = None
        for i, (lo, hi) in enumerate(ranges):
            named = _flatten_with_names(self._host_block(lo, hi))
            if names is None:
                names = [n for n, _ in named]
            np.savez(d / f"store_{i:05d}.npz",
                     **{f"a{j}": leaf for j, (_, leaf) in enumerate(named)})
        manifest = {
            "k": self.k,
            "shard_clients": self.cfg.ckpt_shard_clients,
            "n_shards": len(ranges),
            "names": names or [],
            "store": self.describe(),
        }
        (d / "store_manifest.json").write_text(json.dumps(manifest, indent=1))

    def load_shards(self, step_dir) -> None:
        """Inverse of ``save_shards`` (validates K + leaf names)."""
        d = Path(step_dir)
        manifest = json.loads((d / "store_manifest.json").read_text())
        if manifest["k"] != self.k:
            raise ValueError(
                f"store shards at {d} hold {manifest['k']} clients, but this "
                f"federation has {self.k}"
            )
        want = [n for n, _ in _flatten_with_names(self._host_block(0, 0))]
        if manifest["names"] != want:
            raise ValueError(
                f"store shards at {d} hold leaves {manifest['names']}, but "
                f"this method's client state flattens to {want}"
            )
        ranges = self._shard_ranges()
        if manifest["n_shards"] != len(ranges) or (
                manifest["shard_clients"] != self.cfg.ckpt_shard_clients):
            # shard granularity is part of the on-disk layout; recompute
            # ranges from the writer's granularity so a reader with a
            # different ckpt_shard_clients still restores exactly
            s = int(manifest["shard_clients"])
            ranges = [(lo, min(lo + s, self.k))
                      for lo in range(0, max(self.k, 1), s)]
        for i, (lo, hi) in enumerate(ranges):
            data = np.load(d / f"store_{i:05d}.npz")
            block = [data[f"a{j}"] for j in range(len(want))]
            self._load_host_block(lo, hi, block)

    # subclass hooks: (lo, hi) client range as a host (numpy) pytree, and
    # its inverse taking flat leaves in _flatten_with_names order
    def _host_block(self, lo: int, hi: int) -> Pytree:
        raise NotImplementedError

    def _load_host_block(self, lo: int, hi: int, flat_leaves) -> None:
        raise NotImplementedError


class DeviceStore(CohortStore):
    """The seed layout: the (K, ...) stack resident on device.

    Gather/scatter are the jitted take / ``at[ids].set`` programs the
    runtime owned before §12 — byte-for-byte the same device values, so
    this store IS the baseline the streamed stores are parity-tested
    against."""

    def __init__(self, cfg: StoreConfig, proto: Pytree, k: int):
        super().__init__(cfg, k)
        self._stack = jax.tree.map(
            lambda x: jnp.broadcast_to(jnp.asarray(x), (k,) + jnp.shape(x)),
            proto,
        )
        self._gather = jax.jit(
            lambda full, ids: jax.tree.map(lambda x: x[ids], full)
        )
        self._scatter = jax.jit(
            lambda full, ids, new: jax.tree.map(
                lambda f, n: f.at[ids].set(n), full, new
            )
        )

    def gather(self, ids, shardings=None):
        # shardings are an h2d placement hint; the resident stack already
        # lives where jit wants it, and the engine's in_specs re-lay it out
        self._stats["gathers"] += 1
        return self._gather(self._stack, jnp.asarray(ids))

    def scatter(self, ids, new_states):
        self._stats["scatters"] += 1
        self._stack = self._scatter(
            self._stack, jnp.asarray(ids),
            jax.tree.map(jnp.asarray, new_states),
        )

    def offload(self, tree, force_host=False):
        return jax.device_get(tree) if force_host else tree

    def stacked(self):
        return self._stack

    def load_stacked(self, tree):
        self._stack = jax.tree.map(jnp.asarray, tree)

    def _host_block(self, lo, hi):
        return jax.tree.map(lambda x: np.asarray(x[lo:hi]), self._stack)

    def _load_host_block(self, lo, hi, flat_leaves):
        flat, treedef = jax.tree_util.tree_flatten(self._stack)
        flat = [f.at[lo:hi].set(jnp.asarray(b)) for f, b in zip(flat, flat_leaves)]
        self._stack = jax.tree_util.tree_unflatten(treedef, flat)


class HostStore(CohortStore):
    """Host-at-rest store: numpy (or memmap) stack + LRU device cache.

    See the module docstring for the gather/scatter/overlap semantics.
    The at-rest tree is plain numpy; ``kind="mmap"`` (or a "host" store
    crossing ``mmap_threshold_bytes``) backs each leaf with an
    ``np.memmap`` under ``mmap_dir`` so K is bounded by disk, not RAM.
    """

    def __init__(self, cfg: StoreConfig, proto: Pytree, k: int):
        super().__init__(cfg, k)
        proto_np = jax.tree.map(np.asarray, proto)
        total = k * _tree_bytes(proto_np)
        self.mmapped = cfg.kind == "mmap" or (
            cfg.mmap_threshold_bytes > 0 and total > cfg.mmap_threshold_bytes
        )
        self._mmap_dir = None
        if self.mmapped:
            self._mmap_dir = Path(
                cfg.mmap_dir or tempfile.mkdtemp(prefix="cohort_store_")
            )
            self._mmap_dir.mkdir(parents=True, exist_ok=True)

        def alloc(path_leaf):
            name, leaf = path_leaf
            shape = (k,) + leaf.shape
            if self.mmapped:
                f = self._mmap_dir / (name.replace("/", ".") + ".mmap")
                arr = np.memmap(f, dtype=leaf.dtype, mode="w+", shape=shape)
            else:
                arr = np.empty(shape, leaf.dtype)
            arr[...] = leaf  # broadcast the shared init row-wise
            return arr

        named = _flatten_with_names(proto_np)
        leaves = [alloc(nl) for nl in named]
        self._names = [n for n, _ in named]
        _, self._treedef = jax.tree_util.tree_flatten(proto_np)
        self._data = jax.tree_util.tree_unflatten(self._treedef, leaves)
        self.at_rest_bytes = k * _tree_bytes(proto_np)
        # a "host" store that crossed mmap_threshold_bytes silently spilled
        # to disk — surfaced as a timeline event by the drivers (§13)
        self.promoted = cfg.kind == "host" and self.mmapped
        # deferred write-backs: (ids, device tree) with d2h copies started
        self._writeback: List[Tuple[np.ndarray, Pytree]] = []
        # LRU device cache as a slot buffer (see _slots_* above): one
        # (cache_clients, ...)-stacked device tree (lazily allocated),
        # client id -> slot index in LRU order, and the free slot pool
        self._slots: Optional[Pytree] = None
        self._lru: "OrderedDict[int, int]" = OrderedDict()
        self._free: List[int] = []

    # -- deferred write-back ----------------------------------------------

    def _flush(self):
        """Materialize pending scatters into the numpy stack (FIFO: last
        write wins, matching the scatter order)."""
        for ids, tree in self._writeback:
            host = jax.tree.map(np.asarray, tree)  # copies already in flight
            jax.tree.map(lambda a, h: a.__setitem__(ids, h), self._data, host)
        self._writeback.clear()

    # -- gather / scatter --------------------------------------------------

    def gather(self, ids, shardings=None):
        self._flush()
        self._stats["gathers"] += 1
        ids = np.asarray(ids)
        if shardings is not None or not self.cfg.cache_clients:
            # bypass path: one batched fancy-index + device_put per leaf,
            # placed against the engine's input shardings when given (the
            # mesh backends' per-pod slices land on their pods directly)
            block = jax.tree.map(lambda a: a[ids], self._data)
            self._stats["h2d_bytes"] += _tree_bytes(block)
            if shardings is None:
                return jax.tree.map(jax.device_put, block)
            return jax.tree.map(jax.device_put, block, shardings)
        return self._gather_cached(ids)

    def _ensure_slots(self):
        if self._slots is None:
            cap = self.cfg.cache_clients
            self._slots = jax.tree.map(
                lambda a: jnp.zeros((cap,) + a.shape[1:], a.dtype), self._data
            )
            self._free = list(range(cap - 1, -1, -1))  # pop() fills 0, 1, ...

    def _gather_cached(self, ids):
        """Cohort assembly through the LRU slot buffer: ONE batched
        gather-by-index over [slot buffer ‖ fetched miss block] instead of
        a per-row stack (DESIGN.md §12) — row values bit-identical.

        The output index map is computed BEFORE any cache bookkeeping:
        filling a miss can evict a slot this same cohort still needs (a
        hit older in LRU order, or an earlier miss when K' exceeds the
        capacity), so assembly must see the pre-insertion slot layout.
        """
        id_list = ids.tolist()
        cap = self.cfg.cache_clients
        lru = self._lru
        # duplicate occurrences count per-occurrence, and a duplicated miss
        # fetches (and later writes) its row once per occurrence with the
        # last one winning — the per-row cache's exact semantics
        miss = [i for i in id_list if i not in lru]
        self._stats["cache_hits"] += len(id_list) - len(miss)
        self._stats["cache_misses"] += len(miss)
        self._stats["cache_assembles"] += 1
        block = None
        if miss:
            self._ensure_slots()
            marr = np.asarray(miss, np.int64)
            host_block = jax.tree.map(lambda a: a[marr], self._data)
            self._stats["h2d_bytes"] += _tree_bytes(host_block)
            block = jax.tree.map(jax.device_put, host_block)
        mpos = {i: j for j, i in enumerate(miss)}  # last occurrence wins
        idx = np.asarray(
            [lru[i] if i in lru else cap + mpos[i] for i in id_list],
            np.int64,
        )
        if block is None:
            cohort = _slots_take(self._slots, idx)
        else:
            cohort = _slots_assemble(self._slots, block, idx)
        # LRU bookkeeping, in the per-row cache's exact order: hits touch
        # in cohort order, then misses insert (evicting from the front) in
        # miss order
        for i in id_list:
            if i in lru:
                lru.move_to_end(i)
        pend: Dict[int, int] = {}
        for j, i in enumerate(miss):
            if i in lru:  # duplicated miss: already placed this cohort
                lru.move_to_end(i)
            else:
                if len(lru) >= cap:
                    _, slot = lru.popitem(last=False)
                    self._free.append(slot)
                    self._stats["cache_evictions"] += 1
                lru[i] = self._free.pop()
            pend[i] = j
        # one batched fill for the misses that survived their own cohort's
        # evictions (an id evicted above never reaches the slot buffer,
        # exactly as its row never stayed in the per-row cache)
        live = [(lru[i], j) for i, j in pend.items() if i in lru]
        if live:
            sarr = np.asarray([s for s, _ in live], np.int64)
            jarr = np.asarray([j for _, j in live], np.int64)
            self._slots = _slots_insert(self._slots, block, jarr, sarr)
            self._stats["cache_insert_rows"] += len(live)
        return cohort

    def scatter(self, ids, new_states):
        self._stats["scatters"] += 1
        ids = np.asarray(ids)
        leaves = jax.tree.leaves(new_states)
        on_device = leaves and isinstance(leaves[0], jax.Array)
        if not on_device:
            # host-resident cohort (async deliveries of offloaded rows):
            # write through directly, no d2h copy to wait on
            host = jax.tree.map(np.asarray, new_states)
            jax.tree.map(lambda a, h: a.__setitem__(ids, h), self._data, host)
            for i in ids.tolist():  # cached device rows are now stale
                slot = self._lru.pop(i, None)
                if slot is not None:
                    self._free.append(slot)
            return
        # start the d2h copies now, materialize at the next host access:
        # the copy overlaps the host-side sampling/dispatch of the next
        # round (the §12 overlap timeline)
        jax.tree.map(lambda x: x.copy_to_host_async(), new_states)
        self._stats["d2h_bytes"] += _tree_bytes(new_states)
        self._writeback.append((ids, new_states))
        if self.cfg.cache_clients:
            # write-through into the slot buffer, one batched fill: rows
            # already resident refresh in place; new rows only while free
            # capacity remains (the per-row cache's sequential admission —
            # scatter never evicts)
            self._ensure_slots()
            lru, pend = self._lru, {}
            for j, i in enumerate(ids.tolist()):
                if i in lru:
                    lru.move_to_end(i)
                    pend[i] = j
                elif len(lru) < self.cfg.cache_clients:
                    lru[i] = self._free.pop()
                    pend[i] = j
            if pend:
                sarr = np.asarray([lru[i] for i in pend], np.int64)
                jarr = np.asarray(list(pend.values()), np.int64)
                self._slots = _slots_insert(self._slots, new_states, jarr, sarr)
                self._stats["cache_insert_rows"] += len(pend)

    def offload(self, tree, force_host=False):
        del force_host  # host store: buffered results NEVER pin device memory
        jax.tree.map(
            lambda x: x.copy_to_host_async() if isinstance(x, jax.Array) else None,
            tree,
        )
        return jax.device_get(tree)

    # -- whole-stack access -----------------------------------------------

    def stacked(self):
        self._flush()
        return self._data

    def _drop_cache(self):
        self._slots = None  # reallocated lazily on the next cached access
        self._lru.clear()
        self._free = []

    def load_stacked(self, tree):
        self._writeback.clear()
        self._drop_cache()
        jax.tree.map(
            lambda a, src: a.__setitem__(slice(None), np.asarray(src)),
            self._data, tree,
        )

    def _host_block(self, lo, hi):
        self._flush()
        return jax.tree.map(lambda a: np.asarray(a[lo:hi]), self._data)

    def _load_host_block(self, lo, hi, flat_leaves):
        self._writeback.clear()
        self._drop_cache()
        flat, _ = jax.tree_util.tree_flatten(self._data)
        for a, b in zip(flat, flat_leaves):
            a[lo:hi] = b


# -- batched LRU slot-buffer programs (DESIGN.md §12) -----------------------
#
# The LRU device cache keeps its resident rows in ONE (C, ...)-stacked
# device tree (the "slot buffer") instead of C per-row arrays, so cohort
# assembly and cache fill are single jitted programs over the whole cohort
# rather than per-row stacks/slices.  Pure data movement — row values are
# bit-identical to the per-row representation they replace (asserted in
# tests/test_cohort_store.py).  Module-level jits: shared across stores,
# cached per (capacity, cohort, leaf) shapes.

@jax.jit
def _slots_take(slots, idx):
    """Assemble an all-hit cohort: one gather-by-index per leaf."""
    return jax.tree.map(lambda s: s[idx], slots)


@jax.jit
def _slots_assemble(slots, block, idx):
    """Assemble a mixed cohort from the slot buffer (C rows) and the
    freshly fetched miss block (M rows): index into their concatenation —
    position j < C selects slot j, position C + m selects miss row m."""
    return jax.tree.map(lambda s, b: jnp.concatenate([s, b], 0)[idx],
                        slots, block)


@jax.jit
def _slots_insert(slots, src, jarr, sarr):
    """Batched cache fill: slot[sarr[r]] = src[jarr[r]] for every row r."""
    return jax.tree.map(lambda s, x: s.at[sarr].set(x[jarr]), slots, src)


def make_store(store, proto: Pytree, k: int) -> CohortStore:
    """Store factory (``FLRunConfig.store`` -> a ``CohortStore``)."""
    cfg = as_store_config(store)
    if cfg.kind == "device":
        return DeviceStore(cfg, proto, k)
    return HostStore(cfg, proto, k)
