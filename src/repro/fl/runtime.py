"""Federation runtime: round loop + history, backend-agnostic.

The federation is one SPMD program: per-client states live as stacked
pytrees (leading K axis); each round the K' participating clients are
gathered, a ``FederationEngine`` backend (``repro.fl.engine``) runs the
method's ``client_round`` across them — ``jax.vmap`` on one device, or
``shard_map`` over a client-axis device mesh — uploads are aggregated by
the method's ``server_update``, and the states are scattered back.

The round is executed as jitted *phase programs* (client, eval,
aggregate) built by ``RoundPrograms`` — the cohort gather/scatter around
them belongs to the ``repro.fl.cohort_store`` store (DESIGN.md §12), so
the same programs run whether the K-stack rests on device or on host —
and shared between the synchronous driver here and the asynchronous driver
(``repro.fl.async_``): because both drivers run literally the same
compiled programs on the same operands, the async subsystem's
sync-degenerate guarantee (DESIGN.md §10) is structural — bitwise, not
"up to XLA fusion".  Each phase program compiles once per cohort size, so
recompilation under the async scheduler's micro-cohorts stays bounded.

This is numerically identical to the paper's sequential-client loop (same
initialization, same per-client sampling; verified in
tests/test_fl_runtime.py) but runs K' clients as one vectorized program -
the JAX-idiomatic replacement for a parameter-server process pool
(DESIGN.md §3/§8).  The method object must satisfy the ``FLMethod``
interface documented in ``repro.core.baselines``.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass
from pathlib import Path
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.baselines import FLMethod
from repro.core.pfedsop import theta_from_beta
from repro.data.federated import FederatedData
from repro.fl.cohort_store import make_store
from repro.fl.engine import make_engine
from repro.kernels.dispatch import grad_chunk_count, resolve_update_impl
from repro.optim.reduce import is_pow2
from repro.obs import NOOP, make_obs
from repro.utils.checkpoint import (
    load_checkpoint,
    read_manifest,
    restore_rng_state,
    rng_state_tree,
    save_checkpoint,
)

Pytree = Any

# derived from the Protocol so the contract stays single-sourced
_METHOD_INTERFACE = tuple(
    a for a, v in vars(FLMethod).items() if callable(v) and not a.startswith("_")
)
# the staleness hook is exercised by the async driver alone — a sync-only
# custom method may omit it (AsyncFederation re-validates with the hook)
_SYNC_METHOD_INTERFACE = tuple(
    a for a in _METHOD_INTERFACE if a != "server_update_stale"
)


def validate_method(method, require_stale_hook: bool = False) -> None:
    """Fail fast (with the contract spelled out) on a malformed method.

    The full interface is documented once on ``repro.core.baselines.FLMethod``.
    ``server_update_stale`` is only required when ``require_stale_hook`` is
    set (the async driver is its sole caller, DESIGN.md §10).
    """
    interface = _METHOD_INTERFACE if require_stale_hook else _SYNC_METHOD_INTERFACE
    missing = [a for a in interface if not callable(getattr(method, a, None))]
    if missing or not isinstance(getattr(method, "name", None), str):
        raise TypeError(
            f"{type(method).__name__} does not implement the FLMethod interface "
            f"(missing/uncallable: {missing or ['name']}); see "
            "repro.core.baselines.FLMethod and DESIGN.md §2"
        )


def override_update_impl(method, impl: str):
    """Push a run-level update-impl choice into the method's config.

    Methods expose the knob as an ``update_impl`` field on their frozen
    ``cfg`` dataclass (``PFedSOPConfig`` today); anything else is an error
    because silently running the reference path after an explicit kernel
    request would invalidate impl benchmarks.
    """
    resolve_update_impl(impl)  # validate the name before touching the method
    cfg = getattr(method, "cfg", None)
    if cfg is None or not dataclasses.is_dataclass(cfg) or not hasattr(cfg, "update_impl"):
        raise ValueError(
            f"method {getattr(method, 'name', type(method).__name__)!r} has no "
            "update_impl knob (expected a dataclass `cfg` with an `update_impl` "
            "field, cf. PFedSOPConfig); unset FLRunConfig.update_impl or pick a "
            "method with a kernel dispatch path (DESIGN.md §9)"
        )
    return dataclasses.replace(method, cfg=dataclasses.replace(cfg, update_impl=impl))


@dataclass(frozen=True)
class FLRunConfig:
    """Federation-level run parameters (method hyperparameters live on the
    method object itself, e.g. ``PFedSOPConfig``)."""

    n_clients: int = 100
    participation: float = 0.2  # 20% per round (paper Sec. V-B4)
    rounds: int = 100
    batch: int = 50
    local_iters: int = 0  # 0 = one-local-epoch equivalent (mean client size)
    seed: int = 0
    eval_every: int = 1
    backend: str = "vmap"  # one of repro.fl.engine.BACKENDS
    shards: int = 0  # shard_map only; 0 = auto (largest divisor of K')
    # backend="mesh" only (DESIGN.md §11): mesh spec string for
    # repro.launch.mesh.parse_mesh — "clients[:N]" | "host" | "pod:DxM" |
    # "pods:PxDxM".  The client-role axis of the spec ("pod" on the
    # production mesh) shards the participating-client cohort; rejected
    # for other backends so a layout request is never silently ignored.
    mesh: str = ""
    # Round-boundary output layout (DESIGN.md §11): "replicated" keeps the
    # seed contract — engine outputs leave the client phase fully
    # replicated (an explicit all-gather span) and server aggregation runs
    # over the replicated cohort.  "sharded" opts out of that all-gather
    # on the mesh engines: outputs stay client-sharded at rest (P over the
    # client-role axis), the store scatter/offload consumes the sharded
    # rows, and Eq. 13's mean lowers into a sharded aggregation program
    # whose cohort reductions combine per-shard halving-tree partials in
    # shard order (repro.optim.reduce) — bitwise identical histories to
    # "replicated", asserted in tests/test_output_sharding.py.  Engages
    # per cohort when the client split is active with a power-of-two shard
    # count (the tree-decomposition condition); other cohorts (e.g. async
    # micro-cohorts that fell back to cohort-replicated) keep the
    # replicated path.  Rejected for backend="vmap", whose outputs are
    # born replicated.  Deliberately NOT in the checkpoint fingerprint:
    # it is a layout knob, not a semantics knob.
    output_sharding: str = "replicated"
    # Gradient chunk count of each local SGD step (DESIGN.md §11): the
    # step's gradient is DEFINED as the canonical halving-tree mean over
    # ``grad_chunks`` equal batch chunks (optim.sgd.chunked_value_and_grad).
    # 1 = plain value_and_grad (the seed semantics).  On a mesh whose
    # data-axis size equals this count, the engine shards the per-client
    # batch over the data axis and each device computes one chunk — same
    # numbers, bitwise, by construction.  Changing it CHANGES THE
    # SEMANTICS of training (a different, equally valid gradient), so it
    # IS part of the checkpoint fingerprint.
    grad_chunks: int = 1
    # Round-start update impl override (repro.kernels.dispatch.UPDATE_IMPLS;
    # DESIGN.md §9).  "" = defer to the method's own config (e.g.
    # PFedSOPConfig.update_impl); a non-empty value is pushed into the
    # method at federation construction and errors on methods without the
    # knob — a run-level impl request must never be silently ignored.
    update_impl: str = ""
    # Checkpointing (repro.utils.checkpoint): save the full driver state
    # (stacked client states, broadcast, host RNG state, history, and — for
    # the async driver — scheduler/buffer state) every ``ckpt_every``
    # applied server updates into ``ckpt_dir``.  0/"" disables.  Restart
    # with Federation.restore / AsyncFederation.restore (CLI: --resume on
    # examples/train_federated.py); a restored run reproduces the
    # uninterrupted history bitwise (tests/test_checkpoint_resume.py).
    ckpt_every: int = 0
    ckpt_dir: str = ""
    # Async subsystem (DESIGN.md §10): nested repro.fl.async_.AsyncConfig
    # consumed by AsyncFederation (ignored by the synchronous driver).
    # Typed Any to keep runtime free of an async_ import cycle.
    async_cfg: Any = None
    # Cohort store (DESIGN.md §12): where the (K, ...)-stacked client
    # states live at rest — None/"device" (resident jnp stack, the seed
    # behaviour), "host" (numpy at rest, participants gathered to device
    # per round), "mmap" (disk-backed memmaps), or a full
    # repro.fl.cohort_store.StoreConfig for the cache/threshold knobs.
    # Streamed execution is bitwise identical to the device store
    # (tests/test_cohort_store.py), so this is purely a capacity knob.
    store: Any = None
    # Observability (DESIGN.md §13): None (off — the driver holds the
    # shared NOOP facade and histories are bitwise-identical to an
    # uninstrumented build), a repro.obs.ObsConfig, or a kwargs dict for
    # one.  Deliberately excluded from the checkpoint fingerprint: tracing
    # may be enabled/disabled across a resume (the trace dir itself is
    # fingerprint-stamped and append-only, with a `resume` marker).
    obs: Any = None


class RoundPrograms:
    """Jitted per-phase round programs, cached per cohort size.

    One FL round factors into (1) the client phase over the gathered
    cohort, (2) per-client eval, (3) server aggregation — the cohort
    gather before (1) and the scatter-back after (3) live in the
    ``CohortStore`` (DESIGN.md §12) — and both federation drivers
    (synchronous ``Federation`` here, buffered-asynchronous
    ``AsyncFederation`` in ``repro.fl.async_``) execute the SAME compiled
    programs from this cache.  That sharing is the
    correctness anchor of the async subsystem: in its degenerate
    configuration the async driver feeds identical operands to identical
    programs, so its history matches the synchronous one bitwise
    (DESIGN.md §10, tests/test_async_federation.py).

    Engines (and therefore the client/eval programs, whose mesh is baked
    in at trace time) are cached per ``(cohort size, mesh signature)``
    (DESIGN.md §11) — the signature is the engine's resolved layout id
    (``engine.signature()``), so a micro-cohort whose client split falls
    back to a different layout gets its own program entry instead of
    colliding with the full-cohort one.  The aggregate programs are
    single ``jax.jit`` objects that retrace per operand shape.  The
    async scheduler dispatches in grouped cohorts, so the cache stays
    bounded by the distinct (cohort, layout) pairs actually seen.

    ``strict_shards=False`` (the async driver) falls back when an
    explicitly requested split does not divide a micro-cohort — to the
    largest dividing shard count on the 1-D client mesh, and to an
    unsharded (cohort-replicated) client axis on a multi-pod mesh; the
    synchronous driver keeps the strict §3 validation (a requested split
    must never be silently changed).
    """

    def __init__(self, method, loss_fn, acc_fn, backend: str, shards: int = 0,
                 mesh: str = "", strict_shards: bool = True,
                 output_sharding: str = "replicated", grad_chunks: int = 1):
        self.method = method
        self.loss_fn = loss_fn
        self.acc_fn = acc_fn
        self.backend = backend
        self.shards = shards
        self.mesh = mesh
        self.strict_shards = strict_shards
        self.output_sharding = output_sharding
        self.grad_chunks = grad_chunks
        self._engines: Dict[int, Any] = {}
        self._client: Dict[Any, Any] = {}
        self._eval: Dict[Any, Any] = {}
        self._replicate: Dict[Any, Any] = {}
        self._shardings: Dict[Any, Any] = {}
        self._aggregate_sharded: Dict[Any, Any] = {}
        # the owning driver swaps in its facade; cache-miss events make
        # recompilation visible on the timeline (DESIGN.md §13) and are
        # the ONLY thing obs touches here — programs are identical either way
        self.obs = NOOP
        method_ = method

        def _aggregate(broadcast, uploads):
            return method_.server_update(broadcast, uploads)

        def _aggregate_stale(broadcast, uploads, staleness):
            return method_.server_update_stale(broadcast, uploads, staleness)

        self.aggregate = jax.jit(_aggregate)
        self.aggregate_stale = jax.jit(_aggregate_stale)

    def seen_cohorts(self):
        """Cohort sizes an engine was actually instantiated for (sorted)."""
        return sorted(self._engines)

    def engine(self, cohort: int):
        eng = self._engines.get(cohort)
        if eng is None:
            # micro-cohort split fallbacks live in make_engine(strict=False)
            eng = make_engine(self.backend, cohort, self.shards,
                              mesh=self.mesh, strict=self.strict_shards,
                              data_chunks=self.grad_chunks)
            self._engines[cohort] = eng
            self.obs.event("engine_create", cat="compile", cohort=cohort,
                           signature=eng.signature(), backend=self.backend)
        return eng

    def _key(self, cohort: int):
        """(cohort size, mesh signature) program-cache key (DESIGN.md §11)."""
        return (cohort, self.engine(cohort).signature())

    def client_fn(self, cohort: int):
        """(gathered_states (c-stacked), broadcast, batches) ->
        (new_states, uploads, metrics).  The cohort gather happens in the
        CohortStore before this program runs (DESIGN.md §12) — a pure
        data movement, so the program sees bitwise the same operands the
        previous fused ``x[client_ids]`` gather produced.

        Mesh-backend outputs leave this program still client-sharded: the
        round-boundary all-gather is the separate ``replicate_fn`` program
        (pure data movement — same values, see
        ``MeshBackend.replicate``), so the drivers can time it as its own
        span; compose ``replicate_fn`` before server aggregation."""
        key = self._key(cohort)
        fn = self._client.get(key)
        if fn is None:
            engine = self.engine(cohort)
            method, loss_fn = self.method, self.loss_fn

            def one_client(state, broadcast, batch_seq):
                return method.client_round(loss_fn, state, broadcast, batch_seq)

            def run(gathered_states, broadcast, batches):
                return engine.client_phase_sharded(one_client, gathered_states,
                                                   broadcast, batches)

            fn = jax.jit(run)
            if self.grad_chunks > 1:
                # jit defers tracing to the first call, so the run-level
                # chunk count is announced around every call — the traced
                # body reads it via the dispatch context (DESIGN.md §11)
                jitted, n = fn, self.grad_chunks

                def fn(gathered_states, broadcast, batches):
                    with grad_chunk_count(n):
                        return jitted(gathered_states, broadcast, batches)

            self._client[key] = fn
            self.obs.event("program_cache_miss", cat="compile",
                           program="client", cohort=cohort, signature=key[1])
        return fn

    def sharded_outputs(self, cohort: int) -> bool:
        """Whether this cohort's round runs the §11 sharded-at-rest loop:
        the run opted in, the engine's client split is active, and the
        shard count is a power of two (the halving-tree boundary-alignment
        condition — see repro.optim.reduce).  Cohorts that fail the gate
        (vmap, fallback micro-cohorts, non-pow2 splits) keep the
        replicated path; both paths are bitwise identical."""
        if self.output_sharding != "sharded":
            return False
        eng = self.engine(cohort)
        return bool(getattr(eng, "client_sharded", False)) and is_pow2(
            eng.client_shards)

    def aggregate_fn(self, cohort: int):
        """Server aggregation program for this cohort: the shared host-path
        ``aggregate`` jit, or — under the §11 sharded round loop — the
        engine's ``aggregate_phase`` lowering of ``server_update``, which
        consumes the client-sharded uploads in place (no round-boundary
        all-gather) and reduces over the client-role axis in shard order."""
        if not self.sharded_outputs(cohort):
            return self.aggregate
        key = self._key(cohort)
        fn = self._aggregate_sharded.get(key)
        if fn is None:
            engine = self.engine(cohort)
            method_ = self.method

            def run(broadcast, uploads):
                return engine.aggregate_phase(
                    method_.server_update, broadcast, uploads)

            fn = jax.jit(run)
            self._aggregate_sharded[key] = fn
            self.obs.event("program_cache_miss", cat="compile",
                           program="aggregate_sharded", cohort=cohort,
                           signature=key[1])
        return fn

    def replicate_fn(self, cohort: int):
        """The round-boundary all-gather as its own program (None for
        engines whose outputs are born replicated, i.e. vmap — and None
        under the §11 sharded round loop, which is exactly the point:
        outputs stay client-sharded at rest and the all_gather span
        disappears from the trace)."""
        if self.sharded_outputs(cohort):
            return None
        key = self._key(cohort)
        fn = self._replicate.get(key, False)
        if fn is False:
            rep = getattr(self.engine(cohort), "replicate", None)
            fn = None if rep is None else jax.jit(rep)
            self._replicate[key] = fn
        return fn

    def gather_shardings(self, cohort: int, stacked_struct):
        """Engine input shardings for a gathered cohort tree (cached per
        program key): ``NamedSharding`` per leaf for the mesh backends —
        the host store device_puts against them so a multi-pod mesh
        receives per-pod slices directly (DESIGN.md §12) — or None for
        engines without a mesh placement (vmap)."""
        key = self._key(cohort)
        if key not in self._shardings:
            eng = self.engine(cohort)
            fn = getattr(eng, "input_shardings", None)
            self._shardings[key] = None if fn is None else fn(stacked_struct)
        return self._shardings[key]

    def eval_fn(self, cohort: int):
        """(states (c-stacked), broadcast, test_sets) -> accuracies (c,)."""
        key = self._key(cohort)
        fn = self._eval.get(key)
        if fn is None:
            engine = self.engine(cohort)
            method, acc_fn = self.method, self.acc_fn

            def one_eval(state, broadcast, test):
                params = method.eval_params(state, broadcast)
                return acc_fn(params, test)

            def run(states, broadcast, test_sets):
                return engine.eval_phase(one_eval, states, broadcast, test_sets)

            fn = jax.jit(run)
            self._eval[key] = fn
            self.obs.event("program_cache_miss", cat="compile",
                           program="eval", cohort=cohort, signature=key[1])
        return fn


_HISTORY_KEYS = ("loss", "acc", "round_time", "sim_time")

# metric-histogram bucket edges (DESIGN.md §13): theta spans Eq. 14's
# domain [0, pi] in pi/8 steps; beta/loss use fixed decades so histograms
# from different runs/backends are directly comparable
_THETA_EDGES = tuple(i * np.pi / 8 for i in range(1, 8))
_BETA_EDGES = tuple(i / 10 for i in range(1, 10))
_LOSS_EDGES = (0.01, 0.03, 0.1, 0.3, 1.0, 2.0, 3.0, 5.0, 10.0)


class Federation:
    """Drives ``rounds`` FL rounds of ``method`` over ``data``.

    Sampling (client participation + local SGD batches) is host-side numpy
    seeded by ``run_cfg.seed`` and therefore identical across backends;
    backend choice only changes where the traced client phase executes.

    ``AsyncFederation`` (``repro.fl.async_``) subclasses this driver,
    reusing the construction, the shared phase programs, and the
    checkpoint core; ``_strict_shards`` is the only knob it flips (its
    micro-cohorts may not divide an explicitly requested shard count).

    ``availability`` (optional, ``repro.fl.availability``) attaches the
    client-heterogeneity model to the *simulated clock* only: the
    bulk-synchronous server samples obliviously and then waits for every
    sampled client to come online and finish, so each round advances
    ``sim_time`` by max_i(wait_i + duration_i).  Without a model every
    round costs one simulated unit.  The model never touches numerics or
    the participation RNG (it draws from its own seeded streams), so
    attaching it changes nothing but the ``sim_time`` history column.
    """

    def __init__(
        self,
        method,
        loss_fn: Callable[[Pytree, Dict], jnp.ndarray],
        acc_fn: Callable[[Pytree, Dict], jnp.ndarray],
        init_params: Pytree,
        data: FederatedData,
        run_cfg: FLRunConfig,
        availability=None,
    ):
        self._init_core(method, loss_fn, acc_fn, init_params, data, run_cfg)
        self.availability = availability
        self._obs_open()

    _strict_shards = True

    def _init_core(self, method, loss_fn, acc_fn, init_params, data, run_cfg):
        validate_method(method)
        if run_cfg.output_sharding not in ("replicated", "sharded"):
            raise ValueError(
                f"unknown output_sharding {run_cfg.output_sharding!r}; "
                "choose 'replicated' or 'sharded' (DESIGN.md §11)"
            )
        if run_cfg.output_sharding == "sharded" and run_cfg.backend == "vmap":
            raise ValueError(
                "output_sharding='sharded' is the mesh engines' layout "
                "opt-out (backend='shard_map'/'mesh'); vmap outputs are "
                "born replicated, so the request would be silently ignored"
            )
        if run_cfg.grad_chunks < 1:
            raise ValueError(
                f"grad_chunks must be >= 1, got {run_cfg.grad_chunks}"
            )
        if run_cfg.update_impl:
            method = override_update_impl(method, run_cfg.update_impl)
        self.method = method
        self.loss_fn = loss_fn
        self.acc_fn = acc_fn
        self.data = data
        self.cfg = run_cfg
        self.obs = make_obs(run_cfg.obs)
        self.rng = np.random.RandomState(run_cfg.seed)

        k = run_cfg.n_clients
        assert data.n_clients == k, (data.n_clients, k)
        self.kprime = max(1, int(round(run_cfg.participation * k)))
        self.T = run_cfg.local_iters or data.local_iters(run_cfg.batch)
        self.programs = RoundPrograms(method, loss_fn, acc_fn,
                                      run_cfg.backend, run_cfg.shards,
                                      mesh=run_cfg.mesh,
                                      strict_shards=self._strict_shards,
                                      output_sharding=run_cfg.output_sharding,
                                      grad_chunks=run_cfg.grad_chunks)
        self.programs.obs = self.obs
        # built eagerly: validates backend/shards at construction (§3)
        self.engine = self.programs.engine(self.kprime)

        # same init for every client (paper: "same initialization for all
        # methods"); states stacked on a leading K axis, living at rest in
        # the cohort store (device-resident by default; host/mmap for
        # fleet-scale K — DESIGN.md §12)
        proto = method.init_client(init_params)
        self.store = make_store(run_cfg.store, proto, k)
        # structure/rank probe for the engines' input shardings (the
        # stacked layout never changes, so compute it once)
        self._store_struct = self.store.stacked_struct()
        self.broadcast = method.init_server(init_params)
        self.best_acc = np.zeros(k, np.float64)  # per-client best (Table II)
        # explicit participation mask: ``best_acc > 0`` is NOT a
        # participation proxy — a participating client's best accuracy can
        # legitimately be 0.0 and must still count in mean_best_acc
        self.participated = np.zeros(k, bool)
        self.sim_time = 0.0
        self._round = 0
        self._history = {key: [] for key in _HISTORY_KEYS}

    @property
    def client_states(self):
        """The (K, ...)-stacked client states in the store's at-rest
        representation (jnp for the device store, numpy for host/mmap)."""
        return self.store.stacked()

    @client_states.setter
    def client_states(self, tree):
        self.store.load_stacked(tree)

    # -- observability (DESIGN.md §13) ------------------------------------

    def _obs_fingerprint(self) -> dict:
        """Facets stamped into the trace directory's meta.json.  The
        checkpoint fingerprint plus the method name: two methods (or two
        configs) must never append into one timeline."""
        return {"driver": "sync", "method": self.method.name,
                **self._run_fingerprint()}

    def _obs_open(self) -> None:
        if not self.obs.enabled:
            return
        self.obs.open(self._obs_fingerprint())
        self.obs.event("run_start", engine=self.engine.describe(),
                       rounds=self.cfg.rounds)
        if getattr(self.store, "promoted", False):
            # the host store silently spilled to disk-backed memmaps
            # (capacity threshold, §12) — surface it on the timeline
            self.obs.event("mmap_promote", store=self.store.describe())

    def _observe_client_metrics(self, metrics) -> None:
        """Per-client method diagnostics -> histograms: the Gompertz
        weight beta and its angle theta (recovered host-side from Eq. 14's
        inverse), and the per-round fraction of personalized clients.
        Reads already-materialized host values only."""
        reg = self.obs.metrics
        if reg is None:
            return
        reg.histogram("client.loss", _LOSS_EDGES).observe(
            np.asarray(metrics["loss"], np.float64))
        beta = metrics.get("beta") if hasattr(metrics, "get") else None
        if beta is not None:
            b = np.asarray(beta, np.float64)
            reg.histogram("pfedsop.beta", _BETA_EDGES).observe(b)
            lam = getattr(getattr(self.method, "cfg", None), "lam", None)
            if lam is not None:
                reg.histogram("pfedsop.theta", _THETA_EDGES).observe(
                    theta_from_beta(b, lam))
        if hasattr(metrics, "get") and metrics.get("personalized") is not None:
            reg.gauge("pfedsop.personalized_frac").set(
                float(np.mean(np.asarray(metrics["personalized"], np.float64))))

    def _observe_round(self, t: int, m: dict, dt: float) -> None:
        reg = self.obs.metrics
        if reg is not None:
            reg.counter("rounds").inc()
            reg.gauge("loss").set(m["loss"])
            reg.gauge("acc").set(m["acc"])
            reg.gauge("round_time").set(dt)
            reg.set_gauges("store", self.store.stats())
            self.obs.flush_metrics(step=t, sim_time=self.sim_time)
        self.obs.flush()

    # -- round loop -------------------------------------------------------

    def run_round(self):
        obs = self.obs
        ids = self.rng.choice(self.cfg.n_clients, self.kprime, replace=False)
        batches = self.data.sample_round_batches(self.rng, ids, self.T, self.cfg.batch)
        tests = self.data.client_test_set(ids)
        gathered = obs.timed(
            "gather", self.store.gather,
            ids, self.programs.gather_shardings(self.kprime, self._store_struct)
        )
        out = obs.timed("client", self.programs.client_fn(self.kprime),
                        gathered, self.broadcast, batches)
        # round-boundary all-gather: its own program AND its own span —
        # the phase the mesh-gap analysis needs attributed (§11/§13)
        rep = self.programs.replicate_fn(self.kprime)
        if rep is not None:
            out = obs.timed("all_gather", rep, out)
        new_states, uploads, metrics = out
        # personalized eval against the pre-update broadcast (the model a
        # client would deploy this round)
        accs = obs.timed("eval", self.programs.eval_fn(self.kprime),
                         new_states, self.broadcast, tests)
        self.broadcast = obs.timed("aggregate",
                                   self.programs.aggregate_fn(self.kprime),
                                   self.broadcast, uploads)
        # write-back after upload (§12): the host store starts the d2h
        # copies here and overlaps them with the next round's host-side
        # sampling; the device store applies its jitted at[ids].set.
        # sync=False: blocking would serialize that overlap, so the span
        # measures submit time only.
        obs.timed("scatter", self.store.scatter, ids, new_states, sync=False)

        accs = np.asarray(accs, np.float64)
        self.best_acc[ids] = np.maximum(self.best_acc[ids], accs)
        self.participated[ids] = True
        if self.availability is not None:
            self.sim_time += self.availability.sync_round_duration(ids, self.sim_time)
        else:
            self.sim_time += 1.0
        self._observe_client_metrics(metrics)
        return {
            "loss": float(np.mean(np.asarray(metrics["loss"]))),
            "acc": float(np.mean(accs)),
        }

    def run(self, verbose: bool = False):
        obs = self.obs
        while self._round < self.cfg.rounds:
            t = self._round
            obs.xla_round_start(t)
            t0 = time.perf_counter()
            with obs.span("round", round=t, sim=self.sim_time):
                m = self.run_round()
            dt = time.perf_counter() - t0
            obs.xla_round_end(t)
            self._history["loss"].append(m["loss"])
            self._history["acc"].append(m["acc"])
            self._history["round_time"].append(dt)
            self._history["sim_time"].append(self.sim_time)
            self._round += 1
            if verbose and (t % 10 == 0 or t == self.cfg.rounds - 1):
                obs.log.info(
                    f"[{self.method.name}/{self.engine.name}] round {t:4d} "
                    f"loss={m['loss']:.4f} acc={m['acc']:.4f} ({dt:.2f}s)",
                    event="round", round=t, loss=m["loss"], acc=m["acc"],
                    dt=dt,
                )
            self._observe_round(t, m, dt)
            if (self.cfg.ckpt_every and self.cfg.ckpt_dir
                    and self._round % self.cfg.ckpt_every == 0):
                self.save(self.cfg.ckpt_dir)
        history = self._finalize_history()
        history["engine"] = self.engine.describe()
        obs.close()
        return history

    def _finalize_history(self):
        """History lists + mean_best_acc over the explicit participation
        mask (shared by both drivers — the ``best_acc > 0`` proxy it
        replaces dropped clients whose best accuracy is legitimately 0.0)."""
        history = {key: list(v) for key, v in self._history.items()}
        history["mean_best_acc"] = (
            float(np.mean(self.best_acc[self.participated]))
            if self.participated.any() else 0.0
        )
        return history

    # -- checkpoint / resume ----------------------------------------------

    def _ckpt_tree(self):
        # client_states are NOT in this tree: the store streams them in
        # client-range shards beside arrays.npz (CohortStore.save_shards,
        # DESIGN.md §12), bounding checkpoint working memory at one shard
        return {
            "broadcast": self.broadcast,
            "best_acc": self.best_acc,
            "participated": self.participated,
            "rng": rng_state_tree(self.rng),
            "history": {key: np.asarray(v, np.float64)
                        for key, v in self._history.items()},
        }

    def _run_fingerprint(self) -> dict:
        """Config facets a resumed run must share with the checkpoint
        writer for the restored RNG/clock streams to continue bitwise:
        the sampling/data-shape knobs plus the availability model.
        ``rounds`` is excluded on purpose (extending the budget keeps the
        common prefix bitwise), as are backend/shards/mesh, whose
        histories are parity-tested bit-exact across settings
        (tests/test_engine.py, tests/test_multipod.py; the async driver
        separately fingerprints its resolved ``n_pods``, which changes
        delivery granularity).  The store facets (kind/cache) are stamped
        too: store kinds are parity-tested bitwise as well, but the
        at-rest layout governs how the step directory's shard files are
        restored, so a resume silently changing it is surfaced rather
        than absorbed (DESIGN.md §12).
        """
        av = getattr(self, "availability", None)
        return {
            "seed": self.cfg.seed,
            "n_clients": self.cfg.n_clients,
            "participation": self.cfg.participation,
            "batch": self.cfg.batch,
            "local_iters": self.cfg.local_iters,
            "grad_chunks": self.cfg.grad_chunks,
            "update_impl": self.cfg.update_impl,
            "availability": None if av is None else dataclasses.asdict(av.cfg),
            "store": self.store.describe(),
        }

    def _check_run_fingerprint(self, extra: dict, ckpt_dir) -> None:
        want = self._run_fingerprint()
        if extra.get("run_cfg") != want:
            raise ValueError(
                f"checkpoint at {ckpt_dir} was written with run config "
                f"{extra.get('run_cfg')}, but this driver is configured "
                f"with {want}; resuming across a config change is not a "
                "bitwise continuation"
            )

    def _ckpt_extra(self) -> dict:
        return {"round": self._round, "sim_time": self.sim_time,
                "driver": "sync", "run_cfg": self._run_fingerprint()}

    def save(self, ckpt_dir) -> str:
        """Checkpoint the full driver state after ``self._round`` rounds:
        the driver tree into arrays.npz, the client-states stack streamed
        beside it in store shards (DESIGN.md §12)."""
        path = save_checkpoint(ckpt_dir, self._round, self._ckpt_tree(),
                               extra=self._ckpt_extra())
        self.store.save_shards(path)
        self.obs.event("checkpoint_save", cat="checkpoint", round=self._round)
        return path

    def _load_store_shards(self, ckpt_dir, step: int) -> None:
        self.store.load_shards(Path(ckpt_dir) / f"step_{step:08d}")

    def restore(self, ckpt_dir=None, step=None) -> int:
        """Restore state saved by ``save``; returns the round to resume at.

        Must be called on a freshly constructed, identically configured
        federation (the manifest's stamped config fingerprint rejects a
        mismatch); the resumed run reproduces the uninterrupted loss/acc
        history bitwise (tests/test_checkpoint_resume.py).
        """
        ckpt_dir = ckpt_dir or self.cfg.ckpt_dir
        manifest = read_manifest(ckpt_dir, step)
        ex = manifest["extra"]
        driver = ex.get("driver")
        if driver != "sync":
            raise ValueError(
                f"checkpoint at {ckpt_dir} was written by the {driver!r} "
                "driver, not 'sync'; resume it with the matching driver "
                "(e.g. train_federated.py --mode async)"
            )
        self._check_run_fingerprint(ex, ckpt_dir)
        # pin the validated manifest's step: with step=None a concurrent
        # writer could land a new latest between the two reads, loading
        # arrays the driver/fingerprint checks never saw
        tree, extra = load_checkpoint(ckpt_dir, self._ckpt_template(),
                                      step=manifest["step"])
        self._restore_core(tree, extra)
        self._load_store_shards(ckpt_dir, manifest["step"])
        self.obs.event("checkpoint_restore", cat="checkpoint",
                       round=self._round, step=manifest["step"])
        return self._round

    def _restore_core(self, tree, extra):
        self.broadcast = tree["broadcast"]
        self.best_acc = np.asarray(tree["best_acc"], np.float64)
        self.participated = np.asarray(tree["participated"], bool)
        restore_rng_state(self.rng, tree["rng"])
        self._history = {key: [float(x) for x in np.asarray(v)]
                         for key, v in tree["history"].items()}
        self._round = int(extra["round"])
        self.sim_time = float(extra["sim_time"])

    def _ckpt_template(self):
        tmpl = self._ckpt_tree()
        # history arrays vary in length across checkpoints; only the key
        # names matter for restore (repro.utils.checkpoint matches names)
        tmpl["history"] = {key: np.zeros(0, np.float64) for key in self._history}
        return tmpl


def masked_accuracy(apply_fn):
    """acc_fn factory for padded test sets ({"images","labels","mask"})."""

    def acc(params, test):
        logits = apply_fn(params, test)
        hit = (jnp.argmax(logits, -1) == test["labels"]).astype(jnp.float32)
        return jnp.sum(hit * test["mask"]) / jnp.maximum(jnp.sum(test["mask"]), 1.0)

    return acc
