"""Federation runtime: round loop + history, backend-agnostic.

The federation is one SPMD program: per-client states live as stacked
pytrees (leading K axis); each round the K' participating clients are
gathered, a ``FederationEngine`` backend (``repro.fl.engine``) runs the
method's ``client_round`` across them — ``jax.vmap`` on one device, or
``shard_map`` over a client-axis device mesh — uploads are aggregated by
the method's ``server_update``, and the states are scattered back.  The
whole round (client phase + aggregation + evaluation) is one jitted
function - client_ids are a traced argument so the round function compiles
exactly once per federation.

This is numerically identical to the paper's sequential-client loop (same
initialization, same per-client sampling; verified in
tests/test_fl_runtime.py) but runs K' clients as one vectorized program -
the JAX-idiomatic replacement for a parameter-server process pool
(DESIGN.md §3/§8).  The method object must satisfy the ``FLMethod``
interface documented in ``repro.core.baselines``.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.baselines import FLMethod
from repro.data.federated import FederatedData
from repro.fl.engine import make_engine
from repro.kernels.dispatch import resolve_update_impl

Pytree = Any

# derived from the Protocol so the contract stays single-sourced
_METHOD_INTERFACE = tuple(
    a for a, v in vars(FLMethod).items() if callable(v) and not a.startswith("_")
)


def validate_method(method) -> None:
    """Fail fast (with the contract spelled out) on a malformed method.

    The full interface is documented once on ``repro.core.baselines.FLMethod``.
    """
    missing = [a for a in _METHOD_INTERFACE if not callable(getattr(method, a, None))]
    if missing or not isinstance(getattr(method, "name", None), str):
        raise TypeError(
            f"{type(method).__name__} does not implement the FLMethod interface "
            f"(missing/uncallable: {missing or ['name']}); see "
            "repro.core.baselines.FLMethod and DESIGN.md §2"
        )


def override_update_impl(method, impl: str):
    """Push a run-level update-impl choice into the method's config.

    Methods expose the knob as an ``update_impl`` field on their frozen
    ``cfg`` dataclass (``PFedSOPConfig`` today); anything else is an error
    because silently running the reference path after an explicit kernel
    request would invalidate impl benchmarks.
    """
    resolve_update_impl(impl)  # validate the name before touching the method
    cfg = getattr(method, "cfg", None)
    if cfg is None or not dataclasses.is_dataclass(cfg) or not hasattr(cfg, "update_impl"):
        raise ValueError(
            f"method {getattr(method, 'name', type(method).__name__)!r} has no "
            "update_impl knob (expected a dataclass `cfg` with an `update_impl` "
            "field, cf. PFedSOPConfig); unset FLRunConfig.update_impl or pick a "
            "method with a kernel dispatch path (DESIGN.md §9)"
        )
    return dataclasses.replace(method, cfg=dataclasses.replace(cfg, update_impl=impl))


@dataclass(frozen=True)
class FLRunConfig:
    """Federation-level run parameters (method hyperparameters live on the
    method object itself, e.g. ``PFedSOPConfig``)."""

    n_clients: int = 100
    participation: float = 0.2  # 20% per round (paper Sec. V-B4)
    rounds: int = 100
    batch: int = 50
    local_iters: int = 0  # 0 = one-local-epoch equivalent (mean client size)
    seed: int = 0
    eval_every: int = 1
    backend: str = "vmap"  # one of repro.fl.engine.BACKENDS
    shards: int = 0  # shard_map only; 0 = auto (largest divisor of K')
    # Round-start update impl override (repro.kernels.dispatch.UPDATE_IMPLS;
    # DESIGN.md §9).  "" = defer to the method's own config (e.g.
    # PFedSOPConfig.update_impl); a non-empty value is pushed into the
    # method at federation construction and errors on methods without the
    # knob — a run-level impl request must never be silently ignored.
    update_impl: str = ""


class Federation:
    """Drives ``rounds`` FL rounds of ``method`` over ``data``.

    Sampling (client participation + local SGD batches) is host-side numpy
    seeded by ``run_cfg.seed`` and therefore identical across backends;
    backend choice only changes where the traced client phase executes.
    """

    def __init__(
        self,
        method,
        loss_fn: Callable[[Pytree, Dict], jnp.ndarray],
        acc_fn: Callable[[Pytree, Dict], jnp.ndarray],
        init_params: Pytree,
        data: FederatedData,
        run_cfg: FLRunConfig,
    ):
        validate_method(method)
        if run_cfg.update_impl:
            method = override_update_impl(method, run_cfg.update_impl)
        self.method = method
        self.loss_fn = loss_fn
        self.acc_fn = acc_fn
        self.data = data
        self.cfg = run_cfg
        self.rng = np.random.RandomState(run_cfg.seed)

        k = run_cfg.n_clients
        assert data.n_clients == k, (data.n_clients, k)
        self.kprime = max(1, int(round(run_cfg.participation * k)))
        self.T = run_cfg.local_iters or data.local_iters(run_cfg.batch)
        self.engine = make_engine(run_cfg.backend, self.kprime, run_cfg.shards)

        # same init for every client (paper: "same initialization for all
        # methods"); states stacked on a leading K axis
        proto = method.init_client(init_params)
        self.client_states = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (k,) + jnp.shape(x)), proto
        )
        self.broadcast = method.init_server(init_params)
        self.best_acc = np.zeros(k, np.float64)  # per-client best (Table II)

        self._round_fn = jax.jit(self._make_round_fn())

    def _make_round_fn(self):
        method, loss_fn, acc_fn = self.method, self.loss_fn, self.acc_fn
        engine = self.engine

        def one_client(state, broadcast, batch_seq):
            return method.client_round(loss_fn, state, broadcast, batch_seq)

        def one_eval(state, broadcast, test):
            params = method.eval_params(state, broadcast)
            return acc_fn(params, test)

        def round_fn(client_states, broadcast, client_ids, batches, test_sets):
            gathered = jax.tree.map(lambda x: x[client_ids], client_states)

            new_states, uploads, metrics = engine.client_phase(
                one_client, gathered, broadcast, batches
            )

            # server aggregation over the (possibly cross-shard) client axis
            new_broadcast = method.server_update(broadcast, uploads)

            # personalized eval against the pre-update broadcast (the model a
            # client would deploy this round)
            accs = engine.eval_phase(one_eval, new_states, broadcast, test_sets)

            client_states = jax.tree.map(
                lambda full, new: full.at[client_ids].set(new), client_states, new_states
            )
            return client_states, new_broadcast, metrics, accs

        return round_fn

    def run_round(self):
        ids = self.rng.choice(self.cfg.n_clients, self.kprime, replace=False)
        batches = self.data.sample_round_batches(self.rng, ids, self.T, self.cfg.batch)
        tests = self.data.client_test_set(ids)
        self.client_states, self.broadcast, metrics, accs = self._round_fn(
            self.client_states, self.broadcast, jnp.asarray(ids), batches, tests
        )
        accs = np.asarray(accs, np.float64)
        self.best_acc[ids] = np.maximum(self.best_acc[ids], accs)
        return {
            "loss": float(np.mean(np.asarray(metrics["loss"]))),
            "acc": float(np.mean(accs)),
        }

    def run(self, verbose: bool = False):
        history = {"loss": [], "acc": [], "round_time": []}
        for t in range(self.cfg.rounds):
            t0 = time.perf_counter()
            m = self.run_round()
            dt = time.perf_counter() - t0
            history["loss"].append(m["loss"])
            history["acc"].append(m["acc"])
            history["round_time"].append(dt)
            if verbose and (t % 10 == 0 or t == self.cfg.rounds - 1):
                print(
                    f"[{self.method.name}/{self.engine.name}] round {t:4d} "
                    f"loss={m['loss']:.4f} acc={m['acc']:.4f} ({dt:.2f}s)"
                )
        history["mean_best_acc"] = float(np.mean(self.best_acc[self.best_acc > 0]))
        history["engine"] = self.engine.describe()
        return history


def masked_accuracy(apply_fn):
    """acc_fn factory for padded test sets ({"images","labels","mask"})."""

    def acc(params, test):
        logits = apply_fn(params, test)
        hit = (jnp.argmax(logits, -1) == test["labels"]).astype(jnp.float32)
        return jnp.sum(hit * test["mask"]) / jnp.maximum(jnp.sum(test["mask"]), 1.0)

    return acc
