"""Single-host FL simulation backend.

The federation is one SPMD program: per-client states live as stacked
pytrees (leading K axis); each round the K' participating clients are
gathered, ``jax.vmap`` runs the method's ``client_round`` across them in
parallel, uploads are aggregated by the method's ``server_update``, and the
states are scattered back.  The whole round (client phase + aggregation +
evaluation) is one jitted function - client_ids are a traced argument so
the round function compiles exactly once.

This is numerically identical to the paper's sequential-client loop (same
initialization, same per-client sampling; verified in
tests/test_fl_runtime.py) but runs K' clients as one vectorized program -
the JAX-idiomatic replacement for a parameter-server process pool
(DESIGN.md §3/§8).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.federated import FederatedData

Pytree = Any


@dataclass(frozen=True)
class FLRunConfig:
    n_clients: int = 100
    participation: float = 0.2  # 20% per round (paper Sec. V-B4)
    rounds: int = 100
    batch: int = 50
    local_iters: int = 0  # 0 = one-local-epoch equivalent (mean client size)
    seed: int = 0
    eval_every: int = 1


class Federation:
    def __init__(
        self,
        method,
        loss_fn: Callable[[Pytree, Dict], jnp.ndarray],
        acc_fn: Callable[[Pytree, Dict], jnp.ndarray],
        init_params: Pytree,
        data: FederatedData,
        run_cfg: FLRunConfig,
    ):
        self.method = method
        self.loss_fn = loss_fn
        self.acc_fn = acc_fn
        self.data = data
        self.cfg = run_cfg
        self.rng = np.random.RandomState(run_cfg.seed)

        k = run_cfg.n_clients
        assert data.n_clients == k, (data.n_clients, k)
        self.kprime = max(1, int(round(run_cfg.participation * k)))
        self.T = run_cfg.local_iters or data.local_iters(run_cfg.batch)

        # same init for every client (paper: "same initialization for all
        # methods"); states stacked on a leading K axis
        proto = method.init_client(init_params)
        self.client_states = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (k,) + jnp.shape(x)), proto
        )
        self.broadcast = method.init_server(init_params)
        self.best_acc = np.zeros(k, np.float64)  # per-client best (Table II)

        self._round_fn = jax.jit(self._make_round_fn())

    def _make_round_fn(self):
        method, loss_fn, acc_fn = self.method, self.loss_fn, self.acc_fn

        def round_fn(client_states, broadcast, client_ids, batches, test_sets):
            gathered = jax.tree.map(lambda x: x[client_ids], client_states)

            def one_client(state, batch_seq):
                return method.client_round(loss_fn, state, broadcast, batch_seq)

            new_states, uploads, metrics = jax.vmap(one_client)(gathered, batches)

            new_broadcast = method.server_update(broadcast, uploads)

            def one_eval(state, test):
                params = method.eval_params(state, broadcast)
                return acc_fn(params, test)

            accs = jax.vmap(one_eval)(new_states, test_sets)

            client_states = jax.tree.map(
                lambda full, new: full.at[client_ids].set(new), client_states, new_states
            )
            return client_states, new_broadcast, metrics, accs

        return round_fn

    def run_round(self):
        ids = self.rng.choice(self.cfg.n_clients, self.kprime, replace=False)
        batches = self.data.sample_round_batches(self.rng, ids, self.T, self.cfg.batch)
        tests = self.data.client_test_set(ids)
        self.client_states, self.broadcast, metrics, accs = self._round_fn(
            self.client_states, self.broadcast, jnp.asarray(ids), batches, tests
        )
        accs = np.asarray(accs, np.float64)
        self.best_acc[ids] = np.maximum(self.best_acc[ids], accs)
        return {
            "loss": float(np.mean(np.asarray(metrics["loss"]))),
            "acc": float(np.mean(accs)),
        }

    def run(self, verbose: bool = False):
        history = {"loss": [], "acc": [], "round_time": []}
        for t in range(self.cfg.rounds):
            t0 = time.perf_counter()
            m = self.run_round()
            dt = time.perf_counter() - t0
            history["loss"].append(m["loss"])
            history["acc"].append(m["acc"])
            history["round_time"].append(dt)
            if verbose and (t % 10 == 0 or t == self.cfg.rounds - 1):
                print(
                    f"[{self.method.name}] round {t:4d} loss={m['loss']:.4f} "
                    f"acc={m['acc']:.4f} ({dt:.2f}s)"
                )
        history["mean_best_acc"] = float(np.mean(self.best_acc[self.best_acc > 0]))
        return history


def masked_accuracy(apply_fn):
    """acc_fn factory for padded test sets ({"images","labels","mask"})."""

    def acc(params, test):
        logits = apply_fn(params, test)
        hit = (jnp.argmax(logits, -1) == test["labels"]).astype(jnp.float32)
        return jnp.sum(hit * test["mask"]) / jnp.maximum(jnp.sum(test["mask"]), 1.0)

    return acc
