"""Backend-pluggable federation engine (DESIGN.md §3/§11).

The round logic in ``repro.fl.runtime`` is backend-agnostic: a
``FederationEngine`` decides *where* the per-client work of one round runs.
Three interchangeable backends ship today:

  VmapBackend      single host, single device: the K' participating clients
                   are one ``jax.vmap`` over the stacked client axis (the
                   seed behaviour, and the reference semantics).
  MeshBackend      the general mesh engine (DESIGN.md §11): shard_maps the
                   participating-client axis over the mesh's *client-role*
                   axis (``pod`` on the production `(pod, data, model)`
                   mesh, ``clients`` on the 1-D engine mesh) and each
                   device vmaps its local client slice.  Within a pod the
                   per-client phase replicates over `(data, model)` —
                   except the §9 round-start update, whose flattened-N
                   axis shards over ``model`` (per-shard partial
                   reductions + cross-shard psum for the three Gompertz
                   scalars; `repro.kernels.pfedsop_update`).  In specs
                   come from the composed pspec helpers
                   (`launch/sharding.py::client_stacked_pspecs`), so
                   Megatron-eligible leaves of transformer-family state
                   additionally live model-sharded at rest and are
                   gathered transiently inside the body.
  ShardMapBackend  the 1-D special case of MeshBackend kept under its own
                   name: the client axis over a ``"clients"`` mesh — the
                   §3 layout.

All backends run the *same* traced client function on the *same* stacked
operands and return their outputs **fully replicated** (an explicit
round-boundary all-gather inside the program), so downstream server
aggregation (Eq. 13) compiles to the same mesh-shape-invariant program
everywhere.  That replication is what upgrades backend parity from
"equal up to cross-shard reduction order" to **bitwise** — asserted on a
1-device mesh, a 4-way client mesh and a forced 8-device `(2,2,2)`
multi-pod mesh (tests/test_engine.py, tests/test_multipod.py).

The client function contract is the ``FLMethod`` interface documented in
``repro.core.baselines``; the engine only requires that it is traceable
(vmap/shard_map-safe: no python control flow on traced values).
"""
from __future__ import annotations

import contextlib
from typing import Any, Callable, Optional, Protocol, Union, runtime_checkable

import jax

try:  # moved out of jax.experimental in newer jax releases
    from jax import shard_map
except ImportError:
    from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.kernels.dispatch import (
    client_shard_axis,
    data_shard_axis,
    model_shard_axis,
)
from repro.launch.mesh import MeshSpec, is_auto_clients, parse_mesh, resolve_mesh
from repro.launch.sharding import client_stacked_pspecs

Pytree = Any
CLIENT_AXIS = "clients"


@runtime_checkable
class FederationEngine(Protocol):
    """Executes the data-parallel (per-client) phases of one FL round.

    ``one_client``/``one_eval`` are traced functions of ONE client's slice
    (no leading client axis); every other argument carries a leading
    stacked-client axis except ``broadcast``, which is replicated.
    """

    name: str

    def client_phase(
        self,
        one_client: Callable[[Pytree, Pytree, Pytree], Any],
        gathered_states: Pytree,
        broadcast: Pytree,
        batches: Pytree,
    ) -> Any:
        """(states, broadcast, batches) -> (new_states, uploads, metrics)."""
        ...

    def eval_phase(
        self,
        one_eval: Callable[[Pytree, Pytree, Pytree], Any],
        states: Pytree,
        broadcast: Pytree,
        test_sets: Pytree,
    ) -> Any:
        """(states, broadcast, test_sets) -> per-client accuracies (K',)."""
        ...

    def describe(self) -> dict:
        """Static metadata for logs/benchmarks (backend, shards, ...)."""
        ...


class VmapBackend:
    """Single-host reference backend: one jax.vmap over the client axis."""

    name = "vmap"
    n_pods = 1

    def signature(self) -> str:
        """Engine layout id (RoundPrograms cache key, DESIGN.md §11)."""
        return "vmap"

    def client_phase(self, one_client, gathered_states, broadcast, batches):
        return jax.vmap(one_client, in_axes=(0, None, 0))(
            gathered_states, broadcast, batches
        )

    # single-device outputs are trivially "replicated": the sharded/
    # replicate factoring (see MeshBackend) collapses to the fused phase
    client_phase_sharded = client_phase
    replicate = None

    def eval_phase(self, one_eval, states, broadcast, test_sets):
        return jax.vmap(one_eval, in_axes=(0, None, 0))(
            states, broadcast, test_sets
        )

    def input_shardings(self, tree):
        """No mesh placement: the cohort store's default single-device
        ``device_put`` is already this backend's layout (DESIGN.md §12)."""
        return None

    def describe(self):
        return {"backend": self.name, "shards": 1}


def resolve_shards(kprime: int, n_devices: int, requested: int = 0) -> int:
    """Shard count for a K'-client round on ``n_devices`` local devices.

    The stacked-client axis is split evenly (no padding — padded dummy
    clients would change the server mean, breaking backend equivalence), so
    the shard count must divide K'.  ``requested=0`` picks the largest
    divisor of K' that fits the device count; an explicit request is
    validated strictly.
    """
    if requested < 0:
        raise ValueError(f"shards must be >= 0 (0 = auto), got {requested}")
    if requested:
        if requested > n_devices:
            raise ValueError(
                f"requested {requested} shards but only {n_devices} devices"
            )
        if kprime % requested:
            raise ValueError(
                f"shards={requested} must divide the {kprime} participating "
                "clients per round (no padding; see DESIGN.md §3)"
            )
        return requested
    for n in range(min(kprime, n_devices), 0, -1):
        if kprime % n == 0:
            return n
    return 1


def resolve_client_split(kprime: int, spec: MeshSpec, strict: bool = True) -> bool:
    """Whether a K'-cohort can shard over ``spec``'s client-role axis.

    Unlike the 1-D ``resolve_shards`` (which picks a dividing shard count),
    a mesh's client-axis size is fixed by the spec, so a non-divisor K' has
    no partial split: ``strict=True`` raises (a requested layout must never
    be silently changed, §3); ``strict=False`` — the async driver's
    micro-cohorts — falls back to an unsharded client axis (the cohort
    replicates across pods; the §9 model-sharded update still applies).
    Returns True when the client axis is used, False for the fallback.
    """
    size = spec.client_size
    if spec.client_axis is None or size == 1:
        return False
    if kprime % size == 0:
        return True
    if strict:
        raise ValueError(
            f"mesh {spec.signature()}: client axis {spec.client_axis!r} of "
            f"size {size} must divide the {kprime} participating clients per "
            "round (no padding; see DESIGN.md §3/§11) — pick a dividing pod "
            "count or adjust participation"
        )
    return False


class MeshBackend:
    """Mesh engine: client axis over the client-role axis of a MeshSpec.

    Each device holding a client-axis coordinate runs ``jax.vmap`` over its
    local clients inside ``shard_map``; the remaining mesh axes (``data``,
    ``model``) replicate the per-client phase except where a kernel opts
    into the model axis via the §9 dispatch context
    (``repro.kernels.dispatch.model_shard_axis`` — the model-sharded
    ``pfedsop_update`` layout, DESIGN.md §11).

    Inputs may arrive model-sharded at rest: in-specs come from the
    composed ``client_stacked_pspecs`` (client axis x Megatron param
    rules), and the body transiently all-gathers any model-sharded leaf
    before the per-client compute.  Outputs are returned fully replicated
    (see module docstring — the bitwise-parity contract).
    """

    name = "mesh"

    def __init__(self, kprime: int, spec: MeshSpec, strict: bool = True,
                 data_chunks: int = 0):
        self.kprime = kprime
        self.spec = spec
        self.client_sharded = resolve_client_split(kprime, spec, strict)
        self.mesh = resolve_mesh(spec)
        # FLRunConfig.grad_chunks, threaded through make_engine: when it
        # equals the mesh's data-axis size, the client phase shards the
        # per-client batch over the data axis and each device computes its
        # gradient *chunk* (optim.sgd.chunked_value_and_grad) — same
        # chunk-tree semantics as the in-body path, so histories stay
        # bitwise vs data=1 (DESIGN.md §11).
        self.data_chunks = int(data_chunks)

    @property
    def client_shards(self) -> int:
        return self.spec.client_size if self.client_sharded else 1

    @property
    def n_pods(self) -> int:
        """Pods the async scheduler maps micro-cohorts onto (DESIGN.md
        §11): the client-axis size of an explicit multi-pod mesh; 1
        otherwise (the 1-D client mesh keeps global scheduling)."""
        return (self.spec.client_size
                if self.spec.client_axis == "pod" and self.client_sharded
                else 1)

    def signature(self) -> str:
        """Engine layout id (RoundPrograms cache key, DESIGN.md §11)."""
        sig = self.spec.signature()
        if not self.client_sharded:
            sig += "|cohort-replicated"
        if self.data_chunks > 1:
            sig += f"|data-chunks={self.data_chunks}"
        return sig

    def _in_specs(self, tree):
        caxis = self.spec.client_axis if self.client_sharded else None
        return client_stacked_pspecs(
            tree, caxis, model_axis=self.spec.model_axis,
            msize=self.spec.model_size,
        )

    def _data_split(self, batches) -> bool:
        """Whether this call's batch tree shards over the data axis.

        Engages only when the run-level chunk count equals the data-axis
        size (the local slice must BE one semantic chunk) and every leaf
        carries a stacked (client, step, batch, ...) layout whose batch
        dim (index 2) splits evenly.  Decided per trace from static
        shapes, so a non-dividing batch (e.g. the multipod bench's 25)
        falls back to the in-body chunk path with identical numbers.
        """
        dsize = self.spec.data_size
        if (self.spec.data_axis is None or dsize <= 1
                or self.data_chunks != dsize):
            return False
        leaves = jax.tree.leaves(batches)
        return bool(leaves) and all(
            x.ndim >= 3 and x.shape[2] % dsize == 0 for x in leaves
        )

    def _batch_specs(self, tree):
        """In-specs for a data-sharded batch tree: client axis on the
        stacked dim, data axis on the per-step batch dim (index 2)."""
        caxis = self.spec.client_axis if self.client_sharded else None
        daxis = self.spec.data_axis
        return jax.tree.map(lambda _: P(caxis, None, daxis), tree)

    def _gather_model(self, tree, specs):
        """All-gather any model-sharded dims so the per-client compute sees
        full leaves (transient: storage stays sharded, compute replicates
        across the model axis — the §11 v1 semantics; the model axis does
        real parallel work inside the §9 model-sharded update kernel)."""
        maxis = self.spec.model_axis
        if maxis is None or self.spec.model_size <= 1:
            return tree

        def gather(x, spec):
            # spec dims after the leading client axis map to x's dims 1:
            # inside the body the client axis is local (dim 0 retained)
            for d, ax in enumerate(spec):
                if d == 0:
                    continue  # client axis handled by shard_map itself
                if ax == maxis:
                    x = jax.lax.all_gather(x, maxis, axis=d, tiled=True)
            return x

        return jax.tree.map(gather, tree, specs)

    def _sharded(self, fn, *in_trees, broadcast, replicated: bool = True,
                 data_tree: bool = False):
        # data_tree: the LAST in_tree is a stacked batch tree eligible for
        # data-axis sharding (the client phase; never eval/test sets)
        data_split = data_tree and self._data_split(in_trees[-1])
        specs = [self._in_specs(t) for t in in_trees]
        if data_split:
            specs[-1] = self._batch_specs(in_trees[-1])
        specs = tuple(specs)
        caxis = self.spec.client_axis if self.client_sharded else None
        out_spec = P(caxis) if caxis else P()

        def local(broadcast_, *local_trees):
            local_trees = tuple(
                self._gather_model(t, s) for t, s in zip(local_trees, specs)
            )
            return jax.vmap(fn, in_axes=(0, None) + (0,) * (len(local_trees) - 1))(
                local_trees[0], broadcast_, *local_trees[1:]
            )

        # check_rep=False: jax has no replication rule for pallas_call, so
        # the rep checker rejects the kernel update impl (DESIGN.md §9).
        # Safe here — outputs are re-constrained to replicated at the round
        # boundary (``replicate``), so the check would not tighten anything.
        msize = self.spec.model_size
        with contextlib.ExitStack() as ctx:
            if self.spec.model_axis is not None and msize > 1:
                ctx.enter_context(
                    model_shard_axis(self.spec.model_axis, msize))
            if data_split:
                ctx.enter_context(
                    data_shard_axis(self.spec.data_axis, self.spec.data_size))
            out = shard_map(
                local,
                mesh=self.mesh,
                in_specs=(P(),) + specs,
                out_specs=out_spec,
                check_rep=False,
            )(broadcast, *in_trees)
        return self.replicate(out) if replicated else out

    def replicate(self, out):
        """The round-boundary all-gather: outputs leave the engine fully
        replicated, so server aggregation compiles to the same
        mesh-shape-invariant program everywhere (the bitwise parity
        contract; DESIGN.md §11).  Pure data movement — values are bitwise
        identical whether this runs fused with the client phase or as its
        own program, which is how the observability layer times it as a
        separate span without forking the math (§13)."""
        return jax.lax.with_sharding_constraint(
            out, NamedSharding(self.mesh, P())
        )

    def input_shardings(self, tree):
        """Per-leaf ``NamedSharding`` for a gathered client-stacked cohort
        at this engine's at-rest layout (client axis x Megatron param
        rules — the same ``_in_specs`` the phase programs consume).  The
        host cohort store ``device_put``s each gathered leaf against
        these, so the participants' rows land as per-pod (and per
        model-shard) slices directly instead of a replicated cohort that
        shard_map re-lays out (DESIGN.md §12).  ``tree`` only needs the
        leaf names/ranks (a ShapeDtypeStruct probe works)."""
        specs = self._in_specs(tree)
        return jax.tree.map(lambda s: NamedSharding(self.mesh, s), specs)

    def client_phase(self, one_client, gathered_states, broadcast, batches):
        return self._sharded(one_client, gathered_states, batches,
                             broadcast=broadcast, data_tree=True)

    def client_phase_sharded(self, one_client, gathered_states, broadcast, batches):
        """Client phase WITHOUT the round-boundary all-gather: outputs stay
        client-sharded (P(caxis)); callers compose ``replicate`` before
        aggregation.  The drivers use this factored pair so the all-gather
        is attributable as its own trace span (DESIGN.md §13) — and so the
        §11 sharded-at-rest round loop can drop it entirely, feeding the
        pod-sharded outputs straight into ``aggregate_phase``."""
        return self._sharded(one_client, gathered_states, batches,
                             broadcast=broadcast, replicated=False,
                             data_tree=True)

    def aggregate_phase(self, fn, broadcast, *upload_trees):
        """Server aggregation lowered into the sharded program (§11).

        ``fn(broadcast, *uploads) -> new_broadcast`` is the method's
        ``server_update``, traced inside a shard_map whose upload in-specs
        match ``client_phase_sharded``'s out-specs exactly (client axis on
        dim 0 of every leaf) — no resharding between the phases.  The body
        announces ``client_shard_axis``, so the cohort reductions inside
        ``fn`` (``repro.optim.reduce.cohort_mean``/``cohort_sum``) combine
        shard-local halving-tree partials in shard order: bitwise equal to
        the replicated program by the ordered-decomposition argument in
        ``repro.optim.reduce``.  Output replicates (every device computes
        the identical new broadcast from the gathered partials).
        """
        caxis = self.spec.client_axis
        csize = self.spec.client_size
        specs = tuple(
            jax.tree.map(lambda _: P(caxis), t) for t in upload_trees
        )

        def local(broadcast_, *local_trees):
            with client_shard_axis(caxis, csize):
                return fn(broadcast_, *local_trees)

        return shard_map(
            local,
            mesh=self.mesh,
            in_specs=(P(),) + specs,
            out_specs=P(),
            check_rep=False,
        )(broadcast, *upload_trees)

    def eval_phase(self, one_eval, states, broadcast, test_sets):
        return self._sharded(one_eval, states, test_sets, broadcast=broadcast)

    def describe(self):
        out = {
            "backend": self.name,
            "mesh": self.spec.signature(),
            "shards": self.client_shards,
            "n_pods": self.n_pods,
            "model_shards": self.spec.model_size,
            "devices": [str(d) for d in self.mesh.devices.flat],
        }
        if self.data_chunks > 1:
            out["data_chunks"] = self.data_chunks
        return out


class ShardMapBackend(MeshBackend):
    """1-D special case of ``MeshBackend``: the participating-client axis
    over a ``"clients"`` mesh (DESIGN.md §3), shard count resolved from
    (K', local devices) by ``resolve_shards``."""

    name = "shard_map"

    def __init__(self, kprime: int, shards: int = 0, data_chunks: int = 0):
        self.shards = resolve_shards(kprime, len(jax.devices()), shards)
        super().__init__(kprime, MeshSpec.clients(self.shards, CLIENT_AXIS),
                         data_chunks=data_chunks)

    def describe(self):
        return {
            "backend": self.name,
            "shards": self.shards,
            "devices": [str(d) for d in self.mesh.devices.flat],
        }


BACKENDS = ("vmap", "shard_map", "mesh")


def make_engine(backend: str, kprime: int, shards: int = 0,
                mesh: Union[str, MeshSpec, None] = None,
                strict: bool = True,
                data_chunks: int = 0) -> FederationEngine:
    """Engine factory used by ``Federation`` (selected via FLRunConfig).

    ``mesh`` (a spec string for ``repro.launch.mesh.parse_mesh``, or a
    ``MeshSpec``) selects the layout for ``backend="mesh"`` and is rejected
    elsewhere — like ``shards``, a layout request must never be silently
    ignored.  ``strict=False`` (the async driver's micro-cohorts) lets a
    non-divisor cohort fall back instead of erroring (§3/§11).
    ``data_chunks`` threads ``FLRunConfig.grad_chunks`` to the mesh engines
    (the data-axis local-SGD layout, §11); the vmap backend computes its
    chunks in-body via the dispatch context, so it takes no engine knob.
    """
    if backend == "vmap":
        if shards or mesh:
            raise ValueError(
                "shards/mesh are only meaningful with backend='shard_map'/"
                f"'mesh' (got shards={shards}, mesh={mesh!r} with "
                "backend='vmap')"
            )
        return VmapBackend()
    if backend == "shard_map":
        if mesh:
            raise ValueError(
                "backend='shard_map' is the 1-D client mesh; pass the mesh "
                f"spec (got {mesh!r}) with backend='mesh' instead"
            )
        # async micro-cohorts (strict=False): an explicitly requested split
        # that does not divide the cohort falls back to auto (largest
        # divisor) instead of erroring
        if not strict and shards and kprime % shards:
            shards = 0
        return ShardMapBackend(kprime, shards, data_chunks=data_chunks)
    if backend == "mesh":
        if shards:
            raise ValueError(
                "backend='mesh' takes its client split from the mesh spec's "
                f"client-role axis; shards={shards} is only meaningful with "
                "backend='shard_map'"
            )
        if not mesh:
            raise ValueError(
                "backend='mesh' requires a mesh spec (FLRunConfig.mesh / "
                "--mesh), e.g. 'pods:2x2x2'; see repro.launch.mesh.parse_mesh"
            )
        spec = parse_mesh(mesh) if isinstance(mesh, str) else mesh
        if is_auto_clients(spec):
            spec = MeshSpec.clients(
                resolve_shards(kprime, len(jax.devices())), CLIENT_AXIS)
        return MeshBackend(kprime, spec, strict=strict,
                           data_chunks=data_chunks)
    raise ValueError(f"unknown FL backend {backend!r}; choose from {BACKENDS}")
