"""Backend-pluggable federation engine (DESIGN.md §3).

The round logic in ``repro.fl.runtime`` is backend-agnostic: a
``FederationEngine`` decides *where* the per-client work of one round runs.
Two interchangeable backends ship today:

  VmapBackend      single host, single device: the K' participating clients
                   are one ``jax.vmap`` over the stacked client axis (the
                   seed behaviour, and the reference semantics).
  ShardMapBackend  multi-device: the participating-client axis is sharded
                   across a 1-D ``jax.sharding.Mesh`` ("clients" axis) and
                   each device vmaps its local slice inside
                   ``jax.experimental.shard_map``.  Uploads/metrics/accs
                   come back as global arrays sharded on the client axis, so
                   the server mean over clients (Eq. 13) compiles to a
                   per-shard partial sum + cross-shard psum — the
                   round-boundary all-reduce of DESIGN.md §3.

Both backends run the *same* traced client function on the *same* stacked
operands, so they are numerically equivalent on the same seed: identical on
a 1-device mesh, and equal up to float-reduction order of the cross-shard
aggregation on multi-device meshes (asserted in tests/test_engine.py).

The client function contract is the ``FLMethod`` interface documented in
``repro.core.baselines``; the engine only requires that it is traceable
(vmap/shard_map-safe: no python control flow on traced values).
"""
from __future__ import annotations

from typing import Any, Callable, Protocol, runtime_checkable

import jax

try:  # moved out of jax.experimental in newer jax releases
    from jax import shard_map
except ImportError:
    from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import make_client_mesh
from repro.launch.sharding import client_stacked_pspecs

Pytree = Any
CLIENT_AXIS = "clients"


@runtime_checkable
class FederationEngine(Protocol):
    """Executes the data-parallel (per-client) phases of one FL round.

    ``one_client``/``one_eval`` are traced functions of ONE client's slice
    (no leading client axis); every other argument carries a leading
    stacked-client axis except ``broadcast``, which is replicated.
    """

    name: str

    def client_phase(
        self,
        one_client: Callable[[Pytree, Pytree, Pytree], Any],
        gathered_states: Pytree,
        broadcast: Pytree,
        batches: Pytree,
    ) -> Any:
        """(states, broadcast, batches) -> (new_states, uploads, metrics)."""
        ...

    def eval_phase(
        self,
        one_eval: Callable[[Pytree, Pytree, Pytree], Any],
        states: Pytree,
        broadcast: Pytree,
        test_sets: Pytree,
    ) -> Any:
        """(states, broadcast, test_sets) -> per-client accuracies (K',)."""
        ...

    def describe(self) -> dict:
        """Static metadata for logs/benchmarks (backend, shards, ...)."""
        ...


class VmapBackend:
    """Single-host reference backend: one jax.vmap over the client axis."""

    name = "vmap"

    def client_phase(self, one_client, gathered_states, broadcast, batches):
        return jax.vmap(one_client, in_axes=(0, None, 0))(
            gathered_states, broadcast, batches
        )

    def eval_phase(self, one_eval, states, broadcast, test_sets):
        return jax.vmap(one_eval, in_axes=(0, None, 0))(
            states, broadcast, test_sets
        )

    def describe(self):
        return {"backend": self.name, "shards": 1}


def resolve_shards(kprime: int, n_devices: int, requested: int = 0) -> int:
    """Shard count for a K'-client round on ``n_devices`` local devices.

    The stacked-client axis is split evenly (no padding — padded dummy
    clients would change the server mean, breaking backend equivalence), so
    the shard count must divide K'.  ``requested=0`` picks the largest
    divisor of K' that fits the device count; an explicit request is
    validated strictly.
    """
    if requested < 0:
        raise ValueError(f"shards must be >= 0 (0 = auto), got {requested}")
    if requested:
        if requested > n_devices:
            raise ValueError(
                f"requested {requested} shards but only {n_devices} devices"
            )
        if kprime % requested:
            raise ValueError(
                f"shards={requested} must divide the {kprime} participating "
                "clients per round (no padding; see DESIGN.md §3)"
            )
        return requested
    for n in range(min(kprime, n_devices), 0, -1):
        if kprime % n == 0:
            return n
    return 1


class ShardMapBackend:
    """Shards the participating-client axis across a 1-D device mesh.

    Each device runs ``jax.vmap`` over its K'/shards local clients inside
    ``shard_map``; outputs stay sharded on the client axis so downstream
    cross-client reductions (the server aggregation) become cross-shard
    collectives instead of single-device loops.
    """

    name = "shard_map"

    def __init__(self, kprime: int, shards: int = 0):
        self.kprime = kprime
        self.shards = resolve_shards(kprime, len(jax.devices()), shards)
        self.mesh = make_client_mesh(self.shards, axis_name=CLIENT_AXIS)

    def _sharded(self, fn, *in_trees, broadcast):
        specs = tuple(client_stacked_pspecs(t, CLIENT_AXIS) for t in in_trees)

        def local(broadcast_, *local_trees):
            return jax.vmap(fn, in_axes=(0, None) + (0,) * (len(local_trees) - 1))(
                local_trees[0], broadcast_, *local_trees[1:]
            )

        # check_rep=False: jax has no replication rule for pallas_call, so
        # the rep checker rejects the kernel update impl (DESIGN.md §9).
        # Safe here — every out_spec is fully specified on the client axis,
        # so the check would not tighten anything.
        return shard_map(
            local,
            mesh=self.mesh,
            in_specs=(P(),) + specs,
            out_specs=P(CLIENT_AXIS),
            check_rep=False,
        )(broadcast, *in_trees)

    def client_phase(self, one_client, gathered_states, broadcast, batches):
        return self._sharded(one_client, gathered_states, batches, broadcast=broadcast)

    def eval_phase(self, one_eval, states, broadcast, test_sets):
        return self._sharded(one_eval, states, test_sets, broadcast=broadcast)

    def describe(self):
        return {
            "backend": self.name,
            "shards": self.shards,
            "devices": [str(d) for d in self.mesh.devices.flat],
        }


BACKENDS = ("vmap", "shard_map")


def make_engine(backend: str, kprime: int, shards: int = 0) -> FederationEngine:
    """Engine factory used by ``Federation`` (selected via FLRunConfig)."""
    if backend == "vmap":
        if shards:
            raise ValueError(
                "shards is only meaningful with backend='shard_map' "
                f"(got shards={shards} with backend='vmap')"
            )
        return VmapBackend()
    if backend == "shard_map":
        return ShardMapBackend(kprime, shards)
    raise ValueError(f"unknown FL backend {backend!r}; choose from {BACKENDS}")
