"""Availability-aware discrete-event round scheduler (DESIGN.md §10/§11).

Host-side bookkeeping for the asynchronous federation driver
(``repro.fl.async_``): *when* clients run, never *what* they compute.
Three responsibilities:

- **Grouped dispatch.**  ``dispatch_group`` fills the free concurrency
  slots from the currently online, idle clients with ONE
  ``rng.choice(candidates, m, replace=False)`` draw on the federation's
  participation RandomState.  Grouping matters twice over: clients
  dispatched together share the same broadcast version, so the traced
  client phase runs them through the existing ``FederationEngine``
  backends as one stacked micro-cohort (one jitted SPMD launch, one
  batched §9 kernel call — never K' single-client launches); and in the
  degenerate configuration (everyone online, uniform speeds, concurrency
  = K') the candidate set is exactly ``arange(K)``, making the draw — and
  therefore the whole downstream RNG stream — bitwise identical to the
  synchronous driver's ``rng.choice(K, K', replace=False)``.
- **Completion events.**  A min-heap of ``(completion_time, seq, client,
  pod)`` tuples; ``seq`` is the global dispatch order, so simultaneous
  completions pop in dispatch order — which is what keeps the degenerate
  configuration's upload stacking order identical to the synchronous
  engine output.  ``pop_pod_completions`` pops the *per-pod micro-cohort*
  of every event sharing both the minimal completion time and the pod of
  its earliest-dispatched event, so each pod drains its own completion
  stream (DESIGN.md §11) and deliveries (state scatter + eval) batch
  through the engines per pod.  ``pop_completions`` (the pod-oblivious
  variant, == the single-pod behaviour) remains for callers that want
  the whole timestamp cohort.
- **Wakeups.**  When slots are free but every idle client is offline,
  ``next_dispatch_time`` gives the earliest on-transition to advance the
  clock to.

**Pods** (``n_pods > 1``, the multi-pod `(pod, data, model)` mesh):
dispatched clients are assigned to pods by filling each pod's free slots
in pod order with a *contiguous* run of the single grouped draw — so in
the degenerate configuration pod p holds exactly the p-th contiguous
block of the synchronous cohort, and draining pods in dispatch order
reassembles the synchronous upload order bit-for-bit.  The total
``concurrency`` is split across pods as evenly as possible (earlier pods
take the remainder).

The scheduler is checkpointable: ``state()``/``restore_state`` round-trip
the heap (times/seqs/ids/pods) and the dispatch counter through plain
numpy arrays (repro.utils.checkpoint), and the availability model itself
needs no state (pure function of the seed — see repro.fl.availability).
"""
from __future__ import annotations

import heapq
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.fl.availability import AvailabilityModel
from repro.obs import NOOP


class RoundScheduler:
    """Dispatch/completion bookkeeping over an ``AvailabilityModel``."""

    def __init__(self, availability: AvailabilityModel, concurrency: int,
                 n_pods: int = 1):
        if concurrency < 1:
            raise ValueError(f"concurrency must be >= 1, got {concurrency}")
        if n_pods < 1:
            raise ValueError(f"n_pods must be >= 1, got {n_pods}")
        if n_pods > concurrency:
            raise ValueError(
                f"n_pods={n_pods} exceeds concurrency={concurrency}: a pod "
                "without a dispatch slot would never receive work"
            )
        self.avail = availability
        self.concurrency = concurrency
        self.n_pods = n_pods
        # per-pod slot quota: as even as possible, earlier pods take the
        # remainder (degenerate config: concurrency = K' divisible by pods)
        base, rem = divmod(concurrency, n_pods)
        self._quota = [base + (1 if p < rem else 0) for p in range(n_pods)]
        self._heap: List[Tuple[float, int, int, int]] = []
        self._seq = 0
        self.inflight: Dict[int, int] = {}  # client -> pod
        # observability facade (swapped in by the async driver): a client's
        # dispatch→completion interval is fully known at dispatch (the
        # simulator delays only *delivery*), so the per-client sim-time
        # track is emitted right here (DESIGN.md §13)
        self.obs = NOOP

    # -- dispatch ----------------------------------------------------------

    def free_slots(self) -> int:
        return self.concurrency - len(self.inflight)

    def _pod_inflight(self) -> List[int]:
        counts = [0] * self.n_pods
        for p in self.inflight.values():
            counts[p] += 1
        return counts

    def candidates(self, t: float) -> np.ndarray:
        """Online, idle client ids at time t (sorted — ascending id order,
        matching the synchronous sampler's arange population).

        Always-online models take the vectorized path: a boolean mask over
        ``arange(K)`` instead of a per-client python loop, which is what
        makes fleet-scale (K = 10^6) dispatch tractable.  Both paths
        produce the identical ascending array, so the grouped
        ``rng.choice`` draw is bitwise the same either way.
        """
        if self.avail.always_online:
            if not self.inflight:
                return np.arange(self.avail.n, dtype=np.int64)
            idle = np.ones(self.avail.n, dtype=bool)
            idle[np.fromiter(self.inflight, np.int64, len(self.inflight))] = False
            return np.flatnonzero(idle).astype(np.int64)
        return np.asarray(
            [i for i in range(self.avail.n)
             if i not in self.inflight and self.avail.is_online(i, t)],
            np.int64,
        )

    def dispatch_group(self, t: float, rng: np.random.RandomState) -> np.ndarray:
        """Sample and dispatch a micro-cohort at time t; returns its ids.

        One grouped ``rng.choice`` per event (never per client) on the
        federation's shared participation RandomState — see module
        docstring for why.  The draw is assigned to pods as contiguous
        runs filling each pod's free slots in pod order.  Returns an empty
        array when no slots are free or every idle client is offline.
        """
        want = self.free_slots()
        if want <= 0:
            return np.empty(0, np.int64)
        cands = self.candidates(t)
        m = min(want, len(cands))
        if m == 0:
            return np.empty(0, np.int64)
        ids = rng.choice(cands, m, replace=False)
        counts = self._pod_inflight()
        pos = 0
        for p in range(self.n_pods):
            take = min(self._quota[p] - counts[p], m - pos)
            for i in ids[pos:pos + take].tolist():
                td = t + self.avail.duration(i)
                heapq.heappush(self._heap, (td, self._seq, i, p))
                self._seq += 1
                self.inflight[i] = p
                self.obs.client_span(i, "inflight", t, td, pod=p)
            pos += take
        assert pos == m, (pos, m, self._quota, counts)
        return ids

    # -- completions -------------------------------------------------------

    def next_completion_time(self) -> Optional[float]:
        return self._heap[0][0] if self._heap else None

    def pop_completions(self) -> Tuple[float, List[int]]:
        """Pop the micro-cohort of ALL events at the minimal completion
        time (every pod), in dispatch (seq) order; marks them idle again."""
        if not self._heap:
            raise RuntimeError("pop_completions on an empty event heap")
        t = self._heap[0][0]
        ids: List[int] = []
        while self._heap and self._heap[0][0] == t:
            _, _, i, _ = heapq.heappop(self._heap)
            ids.append(i)
            self.inflight.pop(i, None)
        return t, ids

    def pop_pod_completions(self) -> Tuple[float, int, List[int]]:
        """Pop ONE pod's micro-cohort: all events sharing the minimal
        completion time AND the pod of the earliest-dispatched such event,
        in dispatch (seq) order (DESIGN.md §11 — each pod drains its own
        completion stream).  Events of other pods at the same time stay
        queued for the next pop."""
        if not self._heap:
            raise RuntimeError("pop_pod_completions on an empty event heap")
        t = self._heap[0][0]
        pod = self._heap[0][3]
        ids: List[int] = []
        deferred = []
        while self._heap and self._heap[0][0] == t:
            ev = heapq.heappop(self._heap)
            if ev[3] == pod:
                ids.append(ev[2])
                self.inflight.pop(ev[2], None)
            else:
                deferred.append(ev)
        for ev in deferred:
            heapq.heappush(self._heap, ev)
        return t, pod, ids

    def next_dispatch_time(self, t: float) -> Optional[float]:
        """Earliest time > t when an idle client comes online; None when
        every client is in flight OR no idle client ever comes online
        (a trace model may return inf for permanently-offline clients —
        surfaced as None so callers hit their deadlock error instead of
        advancing the clock to infinity)."""
        if len(self.inflight) >= self.avail.n:
            return None
        if self.avail.always_online:
            # some client is idle and every client is online: dispatchable
            # immediately (``next_online(i, t) == t`` for all i)
            return t
        tn = min(self.avail.next_online(i, t)
                 for i in range(self.avail.n) if i not in self.inflight)
        return tn if np.isfinite(tn) else None

    # -- checkpointing -----------------------------------------------------

    def state(self) -> dict:
        """Heap + counter as arrays (npz-exact; repro.utils.checkpoint)."""
        ev = sorted(self._heap)
        return {
            "times": np.asarray([e[0] for e in ev], np.float64),
            "seqs": np.asarray([e[1] for e in ev], np.int64),
            "ids": np.asarray([e[2] for e in ev], np.int64),
            "pods": np.asarray([e[3] for e in ev], np.int64),
            "seq_counter": np.int64(self._seq),
        }

    def restore_state(self, state: dict) -> None:
        times = np.asarray(state["times"], np.float64)
        seqs = np.asarray(state["seqs"], np.int64)
        ids = np.asarray(state["ids"], np.int64)
        pods = np.asarray(state["pods"], np.int64)
        self._heap = [(float(t), int(s), int(i), int(p))
                      for t, s, i, p in zip(times, seqs, ids, pods)]
        heapq.heapify(self._heap)
        self._seq = int(state["seq_counter"])
        self.inflight = {int(i): int(p) for i, p in zip(ids, pods)}
