"""Availability-aware discrete-event round scheduler (DESIGN.md §10).

Host-side bookkeeping for the asynchronous federation driver
(``repro.fl.async_``): *when* clients run, never *what* they compute.
Three responsibilities:

- **Grouped dispatch.**  ``dispatch_group`` fills the free concurrency
  slots from the currently online, idle clients with ONE
  ``rng.choice(candidates, m, replace=False)`` draw on the federation's
  participation RandomState.  Grouping matters twice over: clients
  dispatched together share the same broadcast version, so the traced
  client phase runs them through the existing ``FederationEngine``
  backends as one stacked micro-cohort (one jitted SPMD launch, one
  batched §9 kernel call — never K' single-client launches); and in the
  degenerate configuration (everyone online, uniform speeds, concurrency
  = K') the candidate set is exactly ``arange(K)``, making the draw — and
  therefore the whole downstream RNG stream — bitwise identical to the
  synchronous driver's ``rng.choice(K, K', replace=False)``.
- **Completion events.**  A min-heap of ``(completion_time, seq, client)``
  triples; ``seq`` is the global dispatch order, so simultaneous
  completions pop in dispatch order — which is what keeps the degenerate
  configuration's upload stacking order identical to the synchronous
  engine output.  ``pop_completions`` pops the *micro-cohort* of every
  event sharing the minimal completion time, so deliveries (state
  scatter + eval) batch through the engines too.
- **Wakeups.**  When slots are free but every idle client is offline,
  ``next_dispatch_time`` gives the earliest on-transition to advance the
  clock to.

The scheduler is checkpointable: ``state()``/``restore_state`` round-trip
the heap and the dispatch counter through plain numpy arrays
(repro.utils.checkpoint), and the availability model itself needs no
state (pure function of the seed — see repro.fl.availability).
"""
from __future__ import annotations

import heapq
from typing import List, Optional, Tuple

import numpy as np

from repro.fl.availability import ClientAvailability


class RoundScheduler:
    """Dispatch/completion bookkeeping over a ``ClientAvailability`` model."""

    def __init__(self, availability: ClientAvailability, concurrency: int):
        if concurrency < 1:
            raise ValueError(f"concurrency must be >= 1, got {concurrency}")
        self.avail = availability
        self.concurrency = concurrency
        self._heap: List[Tuple[float, int, int]] = []
        self._seq = 0
        self.inflight: set = set()

    # -- dispatch ----------------------------------------------------------

    def free_slots(self) -> int:
        return self.concurrency - len(self.inflight)

    def candidates(self, t: float) -> np.ndarray:
        """Online, idle client ids at time t (sorted — ascending id order,
        matching the synchronous sampler's arange population)."""
        return np.asarray(
            [i for i in range(self.avail.n)
             if i not in self.inflight and self.avail.is_online(i, t)],
            np.int64,
        )

    def dispatch_group(self, t: float, rng: np.random.RandomState) -> np.ndarray:
        """Sample and dispatch a micro-cohort at time t; returns its ids.

        One grouped ``rng.choice`` per event (never per client) on the
        federation's shared participation RandomState — see module
        docstring for why.  Returns an empty array when no slots are free
        or every idle client is offline.
        """
        want = self.free_slots()
        if want <= 0:
            return np.empty(0, np.int64)
        cands = self.candidates(t)
        m = min(want, len(cands))
        if m == 0:
            return np.empty(0, np.int64)
        ids = rng.choice(cands, m, replace=False)
        for i in ids.tolist():
            heapq.heappush(self._heap, (t + self.avail.duration(i), self._seq, i))
            self._seq += 1
            self.inflight.add(i)
        return ids

    # -- completions -------------------------------------------------------

    def next_completion_time(self) -> Optional[float]:
        return self._heap[0][0] if self._heap else None

    def pop_completions(self) -> Tuple[float, List[int]]:
        """Pop the micro-cohort of ALL events at the minimal completion
        time, in dispatch (seq) order; marks them idle again."""
        if not self._heap:
            raise RuntimeError("pop_completions on an empty event heap")
        t = self._heap[0][0]
        ids: List[int] = []
        while self._heap and self._heap[0][0] == t:
            _, _, i = heapq.heappop(self._heap)
            ids.append(i)
            self.inflight.discard(i)
        return t, ids

    def next_dispatch_time(self, t: float) -> Optional[float]:
        """Earliest time > t when an idle client comes online (None when
        every client is in flight)."""
        idle = [i for i in range(self.avail.n) if i not in self.inflight]
        if not idle:
            return None
        return min(self.avail.next_online(i, t) for i in idle)

    # -- checkpointing -----------------------------------------------------

    def state(self) -> dict:
        """Heap + counter as arrays (npz-exact; repro.utils.checkpoint)."""
        ev = sorted(self._heap)
        return {
            "times": np.asarray([e[0] for e in ev], np.float64),
            "seqs": np.asarray([e[1] for e in ev], np.int64),
            "ids": np.asarray([e[2] for e in ev], np.int64),
            "seq_counter": np.int64(self._seq),
        }

    def restore_state(self, state: dict) -> None:
        times = np.asarray(state["times"], np.float64)
        seqs = np.asarray(state["seqs"], np.int64)
        ids = np.asarray(state["ids"], np.int64)
        self._heap = [(float(t), int(s), int(i))
                      for t, s, i in zip(times, seqs, ids)]
        heapq.heapify(self._heap)
        self._seq = int(state["seq_counter"])
        self.inflight = set(int(i) for i in ids)
