"""Seeded host-side client-heterogeneity model (DESIGN.md §10).

Production federations are dominated by stragglers and intermittent
availability, not FLOPs: clients differ in compute speed by orders of
magnitude and are online only a fraction of the time.  This module gives
the simulator a *clock* for that world — per-client round durations
(lognormal across clients) and on/off availability traces — without
touching the federation's numerics:

- **Deterministic per seed, independent streams.**  Every draw comes from
  RandomStates keyed by ``(seed, purpose[, client])``, never from the
  federation's participation RNG.  Enabling heterogeneity therefore never
  perturbs cohort or batch sampling — the property the sync-degenerate
  bitwise guarantee of ``repro.fl.async_`` rests on.
- **Pure function of the seed.**  Speeds are drawn once at construction;
  on/off traces are generated lazily per client from per-client
  RandomStates and only ever *extended* forward, so any query order (and
  any checkpoint/restore cut) observes the same trace.  Checkpointing the
  model needs no state.
- **Degenerate-cheap.**  ``availability=1.0`` and ``speed="fixed"`` skip
  the trace machinery entirely: every client is always online with the
  same constant duration — the configuration under which the async driver
  reproduces the synchronous history bitwise.

``ClientAvailability.sync_round_duration`` is the bulk-synchronous cost
model used by the sync driver's simulated clock: the server samples
obliviously and waits for every sampled client to come online and finish,
so one round costs max_i(wait_i + duration_i).  The async scheduler
(``repro.fl.scheduler``) instead dispatches only to online clients —
that asymmetry is exactly what the ``async-engine`` bench measures.
"""
from __future__ import annotations

import bisect
from dataclasses import dataclass

import numpy as np

# seed-stream salts: keep the speed and trace streams disjoint from each
# other (and trivially from the federation's participation RandomState,
# which is seeded with the bare integer seed)
_SPEED_SALT = 0xA11C_0DE
_TRACE_SALT = 0x0F_F0


@dataclass(frozen=True)
class AvailabilityConfig:
    """Client heterogeneity knobs (all times in simulated seconds).

    The defaults are the *degenerate* configuration: fixed uniform speeds,
    always-online clients — the setting under which ``AsyncFederation``
    must reproduce the synchronous history bitwise (DESIGN.md §10).
    """

    speed: str = "fixed"  # "fixed" | "lognormal" per-client multipliers
    mean_duration: float = 1.0  # median client round duration
    sigma: float = 1.0  # lognormal sigma of the speed multipliers
    availability: float = 1.0  # steady-state online fraction; 1.0 = always on
    mean_on: float = 10.0  # mean online-stretch length (exponential)


class ClientAvailability:
    """Per-client speeds + on/off traces, deterministic per (cfg, K, seed)."""

    def __init__(self, cfg: AvailabilityConfig, n_clients: int, seed: int):
        if not 0.0 < cfg.availability <= 1.0:
            raise ValueError(f"availability must be in (0, 1], got {cfg.availability}")
        if cfg.mean_duration <= 0.0 or cfg.mean_on <= 0.0:
            raise ValueError("mean_duration and mean_on must be positive")
        self.cfg = cfg
        self.n = n_clients
        self.seed = seed
        if cfg.speed == "fixed":
            mult = np.ones(n_clients)
        elif cfg.speed == "lognormal":
            rng = np.random.RandomState([seed, _SPEED_SALT])
            mult = rng.lognormal(mean=0.0, sigma=cfg.sigma, size=n_clients)
        else:
            raise ValueError(
                f"unknown speed model {cfg.speed!r}; choose 'fixed' or 'lognormal'"
            )
        # persistent per-client round duration (median = mean_duration)
        self.durations = cfg.mean_duration * mult
        self._always_on = cfg.availability >= 1.0
        # per-client lazily extended traces: (rng, start_on, boundaries)
        # where boundaries[j] is the cumulative time of the j-th on/off flip
        self._traces: dict = {}

    # -- durations ---------------------------------------------------------

    def duration(self, client: int) -> float:
        """Simulated duration of one dispatched client round."""
        return float(self.durations[client])

    # -- on/off traces -----------------------------------------------------

    def _trace(self, client: int, until: float):
        """Trace for ``client`` covering at least ``until`` sim-seconds.

        Alternating exponential on/off periods: mean_on online, and
        mean_off = mean_on * (1 - p) / p offline, which gives steady-state
        online fraction p.  Initial state is online with probability p.
        Extension only appends — the trace is a pure function of the seed.
        """
        tr = self._traces.get(client)
        if tr is None:
            rng = np.random.RandomState([self.seed, _TRACE_SALT, client])
            start_on = bool(rng.random_sample() < self.cfg.availability)
            tr = {"rng": rng, "start_on": start_on, "bounds": [0.0]}
            self._traces[client] = tr
        p = self.cfg.availability
        mean_off = self.cfg.mean_on * (1.0 - p) / p
        bounds = tr["bounds"]
        while bounds[-1] <= until:
            # state during the period being appended alternates from start_on
            on_now = tr["start_on"] ^ (len(bounds) % 2 == 0)
            mean = self.cfg.mean_on if on_now else mean_off
            bounds.append(bounds[-1] + float(tr["rng"].exponential(mean)))
        return tr

    def is_online(self, client: int, t: float) -> bool:
        """Online at time t?  Periods are half-open [start, end)."""
        if self._always_on:
            return True
        tr = self._trace(client, t)
        # bisect on the list itself: np.searchsorted would convert the
        # ever-growing trace to an array on EVERY query, degrading long
        # simulations quadratically with trace length
        j = bisect.bisect_right(tr["bounds"], t) - 1
        return tr["start_on"] ^ (j % 2 == 1)

    def next_online(self, client: int, t: float) -> float:
        """Earliest time >= t at which ``client`` is online."""
        if self._always_on:
            return t
        if self.is_online(client, t):
            return t
        tr = self._trace(client, t)
        bounds = tr["bounds"]
        # bounds[-1] > t after _trace, so this index always exists: it is
        # the end of the offline period containing t == the next on-start
        # (periods strictly alternate)
        j = bisect.bisect_right(bounds, t)
        return float(bounds[j])

    # -- bulk-synchronous cost model --------------------------------------

    def sync_round_duration(self, client_ids, t: float) -> float:
        """Simulated wall-clock of one bulk-synchronous round from time t.

        The synchronous server samples availability-obliviously and waits
        for the full cohort: the round ends when the LAST sampled client
        has come online and finished, so the cost is
        max_i(next_online_i(t) + duration_i) - t.
        """
        ends = [self.next_online(int(i), t) + self.duration(int(i))
                for i in np.asarray(client_ids).tolist()]
        return max(ends) - t
