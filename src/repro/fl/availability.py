"""Host-side client-heterogeneity models (DESIGN.md §10).

Production federations are dominated by stragglers and intermittent
availability, not FLOPs: clients differ in compute speed by orders of
magnitude and are online only a fraction of the time.  This module gives
the simulator a *clock* for that world — per-client round durations and
on/off availability traces — without touching the federation's numerics.
Two implementations of one interface (``duration`` / ``is_online`` /
``next_online`` / ``sync_round_duration``, plus ``.cfg``/``.n`` for the
checkpoint fingerprint):

- ``ClientAvailability`` — the seeded generative model (lognormal speeds,
  exponential on/off renewal process);
- ``TraceAvailability`` — replay-from-file: real-world device traces
  (JSON on/off windows + per-client durations) replayed periodically,
  content-digest-stamped so checkpoint resume rejects a changed trace.

``make_availability`` resolves a config of either flavour; the generative
model's determinism story:

- **Deterministic per seed, independent streams.**  Every draw comes from
  RandomStates keyed by ``(seed, purpose[, client])``, never from the
  federation's participation RNG.  Enabling heterogeneity therefore never
  perturbs cohort or batch sampling — the property the sync-degenerate
  bitwise guarantee of ``repro.fl.async_`` rests on.
- **Pure function of the seed.**  Speeds are drawn once at construction;
  on/off traces are generated lazily per client from per-client
  RandomStates and only ever *extended* forward, so any query order (and
  any checkpoint/restore cut) observes the same trace.  Checkpointing the
  model needs no state.
- **Degenerate-cheap.**  ``availability=1.0`` and ``speed="fixed"`` skip
  the trace machinery entirely: every client is always online with the
  same constant duration — the configuration under which the async driver
  reproduces the synchronous history bitwise.

``ClientAvailability.sync_round_duration`` is the bulk-synchronous cost
model used by the sync driver's simulated clock: the server samples
obliviously and waits for every sampled client to come online and finish,
so one round costs max_i(wait_i + duration_i).  The async scheduler
(``repro.fl.scheduler``) instead dispatches only to online clients —
that asymmetry is exactly what the ``async-engine`` bench measures.
"""
from __future__ import annotations

import bisect
import hashlib
import json
from dataclasses import dataclass, replace
from pathlib import Path

import numpy as np

# seed-stream salts: keep the speed and trace streams disjoint from each
# other (and trivially from the federation's participation RandomState,
# which is seeded with the bare integer seed)
_SPEED_SALT = 0xA11C_0DE
_TRACE_SALT = 0x0F_F0


@dataclass(frozen=True)
class AvailabilityConfig:
    """Client heterogeneity knobs (all times in simulated seconds).

    The defaults are the *degenerate* configuration: fixed uniform speeds,
    always-online clients — the setting under which ``AsyncFederation``
    must reproduce the synchronous history bitwise (DESIGN.md §10).
    """

    speed: str = "fixed"  # "fixed" | "lognormal" per-client multipliers
    mean_duration: float = 1.0  # median client round duration
    sigma: float = 1.0  # lognormal sigma of the speed multipliers
    availability: float = 1.0  # steady-state online fraction; 1.0 = always on
    mean_on: float = 10.0  # mean online-stretch length (exponential)


class AvailabilityModel:
    """Shared interface + the bulk-synchronous cost model.

    Subclasses set ``cfg`` (a frozen dataclass — ``dataclasses.asdict`` of
    it is stamped into checkpoint fingerprints by the drivers) and ``n``,
    and implement ``duration`` / ``is_online`` / ``next_online``.
    """

    cfg = None
    n = 0

    def duration(self, client: int) -> float:
        raise NotImplementedError

    def is_online(self, client: int, t: float) -> bool:
        raise NotImplementedError

    def next_online(self, client: int, t: float) -> float:
        raise NotImplementedError

    @property
    def always_online(self) -> bool:
        """True iff ``is_online`` is identically True (every client, all t).

        An optimization contract, not a heuristic: the async scheduler's
        candidate scan is O(K) python-loop per dispatch event, which at
        fleet scale (K = 10^6) dominates the simulation.  A model that
        returns True here lets the scheduler build the candidate set
        vectorized (same ascending-id order, so the grouped ``rng.choice``
        draw — and the whole downstream history — stays bitwise
        identical).  Default False: correct for any model.
        """
        return False

    def sync_round_duration(self, client_ids, t: float) -> float:
        """Simulated wall-clock of one bulk-synchronous round from time t.

        The synchronous server samples availability-obliviously and waits
        for the full cohort: the round ends when the LAST sampled client
        has come online and finished, so the cost is
        max_i(next_online_i(t) + duration_i) - t.
        """
        ends = [self.next_online(int(i), t) + self.duration(int(i))
                for i in np.asarray(client_ids).tolist()]
        return max(ends) - t


class ClientAvailability(AvailabilityModel):
    """Per-client speeds + on/off traces, deterministic per (cfg, K, seed)."""

    def __init__(self, cfg: AvailabilityConfig, n_clients: int, seed: int):
        if not 0.0 < cfg.availability <= 1.0:
            raise ValueError(f"availability must be in (0, 1], got {cfg.availability}")
        if cfg.mean_duration <= 0.0 or cfg.mean_on <= 0.0:
            raise ValueError("mean_duration and mean_on must be positive")
        self.cfg = cfg
        self.n = n_clients
        self.seed = seed
        if cfg.speed == "fixed":
            mult = np.ones(n_clients)
        elif cfg.speed == "lognormal":
            rng = np.random.RandomState([seed, _SPEED_SALT])
            mult = rng.lognormal(mean=0.0, sigma=cfg.sigma, size=n_clients)
        else:
            raise ValueError(
                f"unknown speed model {cfg.speed!r}; choose 'fixed' or 'lognormal'"
            )
        # persistent per-client round duration (median = mean_duration)
        self.durations = cfg.mean_duration * mult
        self._always_on = cfg.availability >= 1.0
        # per-client lazily extended traces: (rng, start_on, boundaries)
        # where boundaries[j] is the cumulative time of the j-th on/off flip
        self._traces: dict = {}

    # -- durations ---------------------------------------------------------

    def duration(self, client: int) -> float:
        """Simulated duration of one dispatched client round."""
        return float(self.durations[client])

    # -- on/off traces -----------------------------------------------------

    def _trace(self, client: int, until: float):
        """Trace for ``client`` covering at least ``until`` sim-seconds.

        Alternating exponential on/off periods: mean_on online, and
        mean_off = mean_on * (1 - p) / p offline, which gives steady-state
        online fraction p.  Initial state is online with probability p.
        Extension only appends — the trace is a pure function of the seed.
        """
        tr = self._traces.get(client)
        if tr is None:
            rng = np.random.RandomState([self.seed, _TRACE_SALT, client])
            start_on = bool(rng.random_sample() < self.cfg.availability)
            tr = {"rng": rng, "start_on": start_on, "bounds": [0.0]}
            self._traces[client] = tr
        p = self.cfg.availability
        mean_off = self.cfg.mean_on * (1.0 - p) / p
        bounds = tr["bounds"]
        while bounds[-1] <= until:
            # state during the period being appended alternates from start_on
            on_now = tr["start_on"] ^ (len(bounds) % 2 == 0)
            mean = self.cfg.mean_on if on_now else mean_off
            bounds.append(bounds[-1] + float(tr["rng"].exponential(mean)))
        return tr

    @property
    def always_online(self) -> bool:
        return self._always_on

    def is_online(self, client: int, t: float) -> bool:
        """Online at time t?  Periods are half-open [start, end)."""
        if self._always_on:
            return True
        tr = self._trace(client, t)
        # bisect on the list itself: np.searchsorted would convert the
        # ever-growing trace to an array on EVERY query, degrading long
        # simulations quadratically with trace length
        j = bisect.bisect_right(tr["bounds"], t) - 1
        return tr["start_on"] ^ (j % 2 == 1)

    def next_online(self, client: int, t: float) -> float:
        """Earliest time >= t at which ``client`` is online."""
        if self._always_on:
            return t
        if self.is_online(client, t):
            return t
        tr = self._trace(client, t)
        bounds = tr["bounds"]
        # bounds[-1] > t after _trace, so this index always exists: it is
        # the end of the offline period containing t == the next on-start
        # (periods strictly alternate)
        j = bisect.bisect_right(bounds, t)
        return float(bounds[j])


# ---------------------------------------------------------------------------
# Trace-driven availability: replay real-world device traces from a file
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TraceAvailabilityConfig:
    """Replay-from-file availability (``--availability trace:<path>``).

    ``digest`` is the sha256 of the trace file, filled by
    ``TraceAvailability`` at load time: ``dataclasses.asdict(model.cfg)``
    lands in the checkpoint fingerprint (repro.fl.runtime), so resuming
    against a moved OR edited trace file is rejected — the replayed clock
    would not be a bitwise continuation.
    """

    path: str
    digest: str = ""


class TraceAvailability(AvailabilityModel):
    """Replays on/off windows and per-client durations from a JSON file.

    File format (see examples/traces/ for a bundled sample)::

        {"period": 20.0,                    # optional; default max end
         "clients": [
           {"duration": 1.0,                # simulated round duration
            "online": [[0.0, 8.0], [12.0, 20.0]]},   # half-open [s, e)
           ...]}

    Windows must be sorted, non-overlapping and within [0, period]; the
    pattern repeats every ``period`` simulated seconds, so simulations
    longer than the recorded trace keep replaying it (the standard
    device-trace protocol).  A federation larger than the trace maps
    client i onto recorded trace ``i % len(clients)``.  No RNG anywhere:
    the model is a pure function of the file, which is why the content
    digest alone fingerprints it.
    """

    def __init__(self, cfg: TraceAvailabilityConfig, n_clients: int,
                 seed: int = 0):
        del seed  # replay is deterministic; kept for interface symmetry
        raw = Path(cfg.path).read_bytes()
        digest = hashlib.sha256(raw).hexdigest()
        if cfg.digest and cfg.digest != digest:
            raise ValueError(
                f"trace file {cfg.path} has digest {digest[:12]}..., but the "
                f"config pins {cfg.digest[:12]}... - the trace changed on disk"
            )
        self.cfg = replace(cfg, digest=digest)
        self.n = n_clients
        data = json.loads(raw.decode("utf-8"))
        clients = data.get("clients")
        if not clients:
            raise ValueError(f"trace file {cfg.path} has no 'clients' entries")
        ends = [w[1] for c in clients for w in c.get("online", [])]
        self.period = float(data.get("period") or (max(ends) if ends else 0.0))
        if self.period <= 0.0:
            raise ValueError(
                f"trace file {cfg.path} needs a positive period (explicit "
                "'period' or at least one online window)")
        self._durations = []
        self._windows = []
        for j, c in enumerate(clients):
            dur = float(c.get("duration", 1.0))
            if dur <= 0.0:
                raise ValueError(f"trace client {j}: non-positive duration {dur}")
            wins = [(float(s), float(e)) for s, e in c.get("online", [])]
            prev_end = 0.0
            for s, e in wins:
                if not (0.0 <= s < e <= self.period) or s < prev_end:
                    raise ValueError(
                        f"trace client {j}: windows must be sorted, "
                        f"non-overlapping, within [0, {self.period}] "
                        f"(offending window [{s}, {e}))")
                prev_end = e
            self._durations.append(dur)
            self._windows.append(wins)

    def _client(self, client: int) -> int:
        return client % len(self._windows)

    def duration(self, client: int) -> float:
        return self._durations[self._client(client)]

    def is_online(self, client: int, t: float) -> bool:
        tt = t % self.period
        for s, e in self._windows[self._client(client)]:
            if s <= tt < e:
                return True
        return False

    def next_online(self, client: int, t: float) -> float:
        """Earliest time >= t at which ``client`` is online (replay wraps:
        a client with no windows never comes online — rejected upfront by
        the scheduler's deadlock error rather than looping forever)."""
        wins = self._windows[self._client(client)]
        if not wins:
            return float("inf")
        cycle, tt = divmod(t, self.period)
        for s, e in wins:
            if tt < e:
                return t if s <= tt else cycle * self.period + s
        # past the last window: first window of the next cycle
        return (cycle + 1) * self.period + wins[0][0]


def make_availability(cfg, n_clients: int, seed: int) -> AvailabilityModel:
    """Resolve an availability config of either flavour to its model."""
    if isinstance(cfg, TraceAvailabilityConfig):
        return TraceAvailability(cfg, n_clients, seed)
    if isinstance(cfg, AvailabilityConfig):
        return ClientAvailability(cfg, n_clients, seed)
    raise TypeError(
        f"availability config must be AvailabilityConfig or "
        f"TraceAvailabilityConfig, got {type(cfg).__name__}"
    )
