"""Asynchronous federation driver: FedBuff-style buffered aggregation over
an availability-aware discrete-event scheduler (DESIGN.md §10).

The synchronous driver (``repro.fl.runtime.Federation``) models the
idealized bulk-synchronous world: every sampled client finishes instantly
and the server waits for the full cohort.  ``AsyncFederation`` replaces
the round loop with a simulated-time event loop over the same building
blocks:

- ``repro.fl.availability`` supplies per-client speeds and on/off traces
  (seeded independently of the participation RNG);
- ``repro.fl.scheduler`` dispatches work to online idle clients in
  *micro-cohorts* (grouped same-broadcast dispatches) and collects
  uploads at their simulated completion times;
- the server applies an update whenever ``buffer_size`` uploads have
  accumulated; each upload carries its staleness tau (server versions
  elapsed since its dispatch) into the method's ``server_update_stale``
  hook (``repro.core.baselines.FLMethod``).

The hot path is unchanged: micro-cohorts run through the SAME jitted
phase programs (``repro.fl.runtime.RoundPrograms``) and therefore the
same ``FederationEngine`` backends and §9 kernel dispatch as the
synchronous driver — the event loop is host-side python, and programs
are cached per cohort size so recompilation is bounded by the distinct
cohort sizes seen.

Correctness anchor: with the degenerate configuration — every client
always online at uniform speed, ``concurrency = buffer_size = K'`` — the
event loop collapses to lockstep rounds that feed identical operands to
identical programs in identical order, so the loss/acc history matches
the synchronous driver *bitwise* on the same seed, under both engine
backends (tests/test_async_federation.py).  Three properties carry that
guarantee: grouped dispatch consumes the participation RNG exactly like
the synchronous sampler (see ``RoundScheduler.dispatch_group``), the
heterogeneity model draws from its own seeded streams, and an all-fresh
buffer takes the plain aggregation program (the staleness hook is the
identity at tau = 0 — itself asserted bitwise in the tests).

History semantics: one entry per *applied server update* (version), so
"rounds" budgets are comparable across drivers; ``sim_time`` is the
simulated wall-clock at which each update was applied — the metric the
``async-engine`` bench compares against the synchronous driver's
straggler-bound clock.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.fl.availability import AvailabilityConfig, make_availability
from repro.fl.runtime import Federation, FLRunConfig, validate_method
from repro.fl.scheduler import RoundScheduler
from repro.utils.checkpoint import load_checkpoint, read_manifest

Pytree = Any

# event-loop steps without an applied server update before we declare the
# simulation wedged (a generous bound: every step dispatches, advances the
# clock, or delivers, so real configurations flush far sooner)
_MAX_IDLE_STEPS = 100_000

# staleness histogram edges (DESIGN.md §13): τ in powers of two (counts[0]
# is the fresh τ=0 bucket), discount s(τ) ∈ (0, 1] in tenths
_TAU_EDGES = (1.0, 2.0, 4.0, 8.0, 16.0, 32.0)
_DISCOUNT_EDGES = tuple(i / 10 for i in range(1, 10))


@dataclass(frozen=True)
class AsyncConfig:
    """Async-subsystem knobs, nested under ``FLRunConfig.async_cfg``.

    The defaults are the sync-degenerate configuration: ``buffer_size``
    and ``concurrency`` of 0 resolve to K' (the synchronous cohort size),
    and the default ``AvailabilityConfig`` is always-online uniform speed.
    """

    buffer_size: int = 0  # uploads per server update; 0 = K'
    concurrency: int = 0  # clients kept in flight; 0 = K'
    # AvailabilityConfig (seeded on/off + speed model) or
    # TraceAvailabilityConfig (replay-from-file; DESIGN.md §10) — resolved
    # by repro.fl.availability.make_availability
    availability: Any = field(default_factory=AvailabilityConfig)


class AsyncFederation(Federation):
    """Buffered asynchronous federation over a simulated client population.

    Construction mirrors ``Federation`` (same method/loss/acc/data/config
    contract) plus an ``AsyncConfig`` — either passed explicitly or nested
    as ``run_cfg.async_cfg``.  ``run()`` executes until
    ``run_cfg.rounds`` server updates have been applied.
    """

    _strict_shards = False  # micro-cohorts may not divide a requested split

    def __init__(self, method, loss_fn, acc_fn, init_params, data,
                 run_cfg: FLRunConfig, async_cfg: Optional[AsyncConfig] = None):
        # the async driver is the sole caller of server_update_stale, so
        # the hook is required here (and only here), before _init_core
        # touches the method
        validate_method(method, require_stale_hook=True)
        self._init_core(method, loss_fn, acc_fn, init_params, data, run_cfg)
        acfg = async_cfg or run_cfg.async_cfg or AsyncConfig()
        if not isinstance(acfg, AsyncConfig):
            raise TypeError(f"async_cfg must be an AsyncConfig, got {type(acfg)}")
        self.async_cfg = acfg
        self.buffer_size = acfg.buffer_size or self.kprime
        self.concurrency = acfg.concurrency or self.kprime
        if self.buffer_size < 1:
            raise ValueError(f"buffer_size must be >= 1, got {self.buffer_size}")
        self.availability = make_availability(
            acfg.availability, run_cfg.n_clients, run_cfg.seed
        )
        # multi-pod mesh (DESIGN.md §11): micro-cohorts map onto the mesh's
        # pods and each pod drains its own completion stream; 1 elsewhere
        self.n_pods = getattr(self.engine, "n_pods", 1)
        self.scheduler = RoundScheduler(self.availability, self.concurrency,
                                        n_pods=self.n_pods)
        self.scheduler.obs = self.obs
        # in-flight results, computed at dispatch (the simulator needs no
        # delayed compute — only delayed *delivery*): client -> slices
        self._pending: Dict[int, dict] = {}
        # completed uploads awaiting aggregation (FedBuff buffer), in
        # delivery order: dicts of (client, upload, loss, acc, version)
        self._buffer: List[dict] = []
        self._history["staleness"] = []
        self._t0 = time.perf_counter()
        self._obs_open()

    @property
    def version(self) -> int:
        """Applied server updates so far (the FedBuff 'server version')."""
        return self._round

    def _obs_fingerprint(self) -> dict:
        return {**super()._obs_fingerprint(), "driver": "async",
                "async": self._acfg_fingerprint()}

    # -- event loop --------------------------------------------------------

    def run(self, verbose: bool = False):
        self._t0 = time.perf_counter()
        obs = self.obs
        idle = 0
        while self._round < self.cfg.rounds:
            v0 = self._round
            # version-window profiling: the window opens while version v0
            # is current and closes at the step that advances past it
            obs.xla_round_start(v0)
            self._step()
            if self._round > v0:
                obs.xla_round_end(v0)
            idle = 0 if self._round > v0 else idle + 1
            if idle > _MAX_IDLE_STEPS:
                raise RuntimeError(
                    f"async event loop made no progress for {idle} steps "
                    f"(version {self._round}, sim_time {self.sim_time}); "
                    "check the availability configuration"
                )
            if verbose and self._round > v0 and (
                    self._round % 10 == 0 or self._round == self.cfg.rounds):
                obs.log.info(
                    f"[{self.method.name}/async] version {self._round:4d} "
                    f"loss={self._history['loss'][-1]:.4f} "
                    f"acc={self._history['acc'][-1]:.4f} "
                    f"sim_t={self.sim_time:.2f} "
                    f"tau={self._history['staleness'][-1]:.2f}",
                    event="version", version=self._round,
                    loss=self._history["loss"][-1],
                    acc=self._history["acc"][-1], sim_time=self.sim_time,
                    tau=self._history["staleness"][-1],
                )
        history = self._finalize_history()
        # describe an engine that actually ran (the largest cohort seen):
        # with concurrency < K' a kprime-sized engine never executes, and
        # describing a freshly built one could report e.g. a shard count
        # no micro-cohort used
        seen = self.programs.seen_cohorts()
        history["engine"] = {
            **self.programs.engine(seen[-1] if seen else self.kprime).describe(),
            "mode": "async",
            "cohort_sizes": seen,
            "buffer_size": self.buffer_size,
            "concurrency": self.concurrency,
        }
        obs.close()
        return history

    def _step(self):
        """One event-loop transition: dispatch at the current sim time if
        possible, else advance the clock to the next event (completion or
        availability wakeup) and deliver any completions."""
        # a restored checkpoint written by a non-final flush of a
        # multi-flush delivery still holds >= buffer_size uploads; the
        # uninterrupted run applied those flushes before dispatching
        # again, so drain first (a no-op otherwise: _deliver drains)
        self._drain()
        # likewise, a checkpoint written by a flush inside the per-pod
        # same-timestamp drain below still holds the OTHER pods'
        # completions due at the current sim_time; the uninterrupted run
        # delivered every same-time pod cohort before drawing from the
        # participation RNG again, so deliver them before dispatching
        # (a no-op outside resume: dispatched durations are positive, so
        # completions are always strictly in the future here)
        while self.scheduler.next_completion_time() is not None and \
                self.scheduler.next_completion_time() <= self.sim_time:
            _, _, done = self.scheduler.pop_pod_completions()
            self._deliver(done)
        if self._round >= self.cfg.rounds:
            return  # the drain finished the budget; don't dispatch past it
        ids = self.scheduler.dispatch_group(self.sim_time, self.rng)
        if len(ids):
            self._dispatch(ids)
        tc = self.scheduler.next_completion_time()
        if tc is None:
            # nothing in flight: everyone idle is offline; advance to the
            # earliest on-transition and retry dispatch there
            tn = self.scheduler.next_dispatch_time(self.sim_time)
            if tn is None:
                raise RuntimeError("async scheduler deadlock: no clients in "
                                   "flight and none coming online")
            self.sim_time = tn
            return
        if self.scheduler.free_slots() > 0:
            # free slots but every idle client offline: wake early if one
            # comes online before the next completion (keeps the pipeline
            # full instead of idling the free slots until a completion)
            tn = self.scheduler.next_dispatch_time(self.sim_time)
            if tn is not None and tn < tc:
                self.sim_time = tn
                return
        # deliver EVERY per-pod micro-cohort at the next completion time
        # before returning (each pod drains its own stream, DESIGN.md §11;
        # draining the whole timestamp before the next dispatch_group is
        # what keeps the degenerate config's RNG consumption identical to
        # the synchronous sampler's round pattern)
        self.sim_time = tc
        while self.scheduler.next_completion_time() == self.sim_time:
            _, _, done = self.scheduler.pop_pod_completions()
            self._deliver(done)

    def _dispatch(self, ids: np.ndarray):
        """Run the micro-cohort's client phase with the CURRENT broadcast.

        Results are computed now (the broadcast version is what matters;
        delaying the FLOPs would model nothing) but delivered only at
        each client's simulated completion time.  Batch sampling draws
        from the shared participation RNG in one grouped call — the same
        consumption pattern as the synchronous driver.
        """
        obs = self.obs
        obs.event("dispatch", track="async", sim=self.sim_time,
                  cohort=len(ids), version=self._round)
        batches = self.data.sample_round_batches(self.rng, ids, self.T, self.cfg.batch)
        gathered = obs.timed(
            "gather", self.store.gather,
            ids, self.programs.gather_shardings(len(ids), self._store_struct),
            sim=self.sim_time,
        )
        out = obs.timed("client", self.programs.client_fn(len(ids)),
                        gathered, self.broadcast, batches, sim=self.sim_time)
        # round-boundary all-gather as its own program/span (see
        # Federation.run_round); None on vmap, whose outputs are born
        # replicated
        rep = self.programs.replicate_fn(len(ids))
        if rep is not None:
            out = obs.timed("all_gather", rep, out, sim=self.sim_time)
        new_states, uploads, metrics = out
        self._observe_client_metrics(metrics)
        # route in-flight results through the store's offload policy
        # (DESIGN.md §12): a host/mmap store ALWAYS host-copies — buffered
        # uploads must never pin device memory — and the device store
        # host-copies on the sharded backends only, where pending results
        # outlive this micro-cohort's engine mesh and a later delivery may
        # feed them to a DIFFERENT cohort's program (different mesh device
        # set) — a slice of a multi-device-committed array would conflict
        # at that jit boundary.  Mirrors what the checkpoint path stores;
        # bitwise-exact round trip.  VmapBackend has no mesh, so the
        # device store keeps its results on device.
        new_states, uploads = self.store.offload(
            (new_states, uploads), force_host=self.cfg.backend != "vmap"
        )
        losses = np.asarray(metrics["loss"], np.float32)
        for j, i in enumerate(ids.tolist()):
            self._pending[i] = {
                "state": jax.tree.map(lambda x: x[j], new_states),
                "upload": jax.tree.map(lambda x: x[j], uploads),
                "loss": losses[j],
                "version": self._round,
            }

    def _deliver(self, done: List[int]):
        """Collect a completed micro-cohort: scatter its post-training
        states into the K-stack, evaluate against the current broadcast
        (matching the synchronous pre-update eval semantics), and append
        its uploads to the aggregation buffer — flushing whenever
        ``buffer_size`` is reached."""
        obs = self.obs
        obs.event("deliver", track="async", sim=self.sim_time,
                  cohort=len(done), version=self._round)
        items = [self._pending.pop(i) for i in done]
        stacked = jax.tree.map(
            lambda *xs: jnp.stack(xs), *[it["state"] for it in items]
        )
        dn = np.asarray(done, np.int64)
        tests = self.data.client_test_set(dn)
        accs = obs.timed("eval", self.programs.eval_fn(len(done)),
                         stacked, self.broadcast, tests, sim=self.sim_time)
        accs = np.asarray(accs, np.float64)
        self.best_acc[dn] = np.maximum(self.best_acc[dn], accs)
        self.participated[dn] = True
        # sync=False: the host store's d2h write-back is deliberately
        # deferred/overlapped (§12) — the span records submit time only
        obs.timed("scatter", self.store.scatter, dn, stacked,
                  sync=False, sim=self.sim_time)
        # append the WHOLE cohort before flushing: a checkpoint written by a
        # flush must see every delivered upload in the buffer (or already
        # aggregated) — flushing mid-append would let ckpt_every cut the
        # not-yet-appended tail of the cohort out of the saved state.
        # ``sim_t`` (delivery time) exists for the per-client buffered-wait
        # track only — checkpoints don't carry it, so a restored item falls
        # back to the flush time (see _flush).
        for it, i, a in zip(items, done, accs):
            self._buffer.append({
                "client": int(i),
                "upload": it["upload"],
                "loss": it["loss"],
                "acc": a,
                "version": it["version"],
                "sim_t": self.sim_time,
            })
        self._drain()

    def _drain(self):
        """Apply buffered updates until the buffer drops below
        ``buffer_size`` — capped at the round budget, so a delivery
        holding several flushes' worth of uploads never pushes the
        history past ``cfg.rounds`` applied server updates."""
        while (len(self._buffer) >= self.buffer_size
               and self._round < self.cfg.rounds):
            self._flush()

    def _flush(self):
        """Apply one buffered server update (version += 1)."""
        obs = self.obs
        items = self._buffer[: self.buffer_size]
        del self._buffer[: self.buffer_size]
        uploads = jax.tree.map(
            lambda *xs: jnp.stack(xs), *[it["upload"] for it in items]
        )
        tau = np.asarray([self._round - it["version"] for it in items], np.int64)
        if tau.any():
            self.broadcast = obs.timed(
                "aggregate_stale", self.programs.aggregate_stale,
                self.broadcast, uploads, jnp.asarray(tau, jnp.int32),
                sim=self.sim_time,
            )
        else:
            # all-fresh buffer: the staleness hook is the identity at
            # tau = 0 (asserted bitwise in tests/test_async_federation),
            # so take the plain aggregation program — the same compiled
            # program the synchronous driver runs, which makes the
            # sync-degenerate guarantee structural
            self.broadcast = obs.timed(
                "aggregate", self.programs.aggregate,
                self.broadcast, uploads, sim=self.sim_time,
            )
        self._round += 1
        dt = time.perf_counter() - self._t0
        self._t0 = time.perf_counter()
        self._history["loss"].append(
            float(np.mean(np.asarray([it["loss"] for it in items], np.float32)))
        )
        self._history["acc"].append(
            float(np.mean(np.asarray([it["acc"] for it in items], np.float64)))
        )
        self._history["round_time"].append(dt)
        self._history["sim_time"].append(self.sim_time)
        self._history["staleness"].append(float(tau.mean()))
        self._observe_flush(items, tau, dt)
        if (self.cfg.ckpt_every and self.cfg.ckpt_dir
                and self._round % self.cfg.ckpt_every == 0):
            self.save(self.cfg.ckpt_dir)

    def _observe_flush(self, items, tau: np.ndarray, dt: float) -> None:
        """Per-applied-version observability (DESIGN.md §13): the flush
        event with its τ annotations, the per-client buffered-wait track,
        and the staleness histograms — τ itself plus the effective
        FedBuff discount s(τ) = (1+τ)^(-staleness_exp) the stale path
        blends with (``repro.core.pfedsop.staleness_discount``).  Pure
        reads of host values the flush already produced."""
        obs = self.obs
        v = self._round - 1
        obs.event("buffer_flush", track="async", sim=self.sim_time,
                  version=v, n=len(items), tau_mean=float(tau.mean()),
                  tau_max=int(tau.max()), stale=bool(tau.any()))
        if obs.tracer is not None:
            for it in items:
                obs.client_span(
                    it["client"], "buffered",
                    it.get("sim_t", self.sim_time), self.sim_time,
                    tau=int(self._round - 1 - it["version"]), version=v)
        reg = obs.metrics
        if reg is not None:
            reg.counter("versions").inc()
            reg.gauge("loss").set(self._history["loss"][-1])
            reg.gauge("acc").set(self._history["acc"][-1])
            reg.gauge("round_time").set(dt)
            reg.gauge("staleness").set(float(tau.mean()))
            reg.histogram("async.tau", _TAU_EDGES).observe(tau)
            exp_ = getattr(getattr(self.method, "cfg", None),
                           "staleness_exp", None)
            if exp_ is not None:
                reg.histogram("async.stale_discount", _DISCOUNT_EDGES).observe(
                    (1.0 + tau.astype(np.float64)) ** -float(exp_))
            reg.set_gauges("store", self.store.stats())
            obs.flush_metrics(step=v, sim_time=self.sim_time)
        obs.flush()

    # -- checkpoint / resume ----------------------------------------------

    def _ckpt_tree(self):
        tree = super()._ckpt_tree()
        tree["sched"] = self.scheduler.state()
        if self._pending:
            ids = sorted(self._pending)
            items = [self._pending[i] for i in ids]
            tree["pending"] = {
                "ids": np.asarray(ids, np.int64),
                "versions": np.asarray([it["version"] for it in items], np.int64),
                "loss": np.asarray([it["loss"] for it in items], np.float32),
                "states": jax.tree.map(
                    lambda *xs: jnp.stack(xs), *[it["state"] for it in items]
                ),
                "uploads": jax.tree.map(
                    lambda *xs: jnp.stack(xs), *[it["upload"] for it in items]
                ),
            }
        if self._buffer:
            items = self._buffer
            tree["buffer"] = {
                "ids": np.asarray([it["client"] for it in items], np.int64),
                "versions": np.asarray([it["version"] for it in items], np.int64),
                "loss": np.asarray([it["loss"] for it in items], np.float32),
                "acc": np.asarray([it["acc"] for it in items], np.float64),
                "uploads": jax.tree.map(
                    lambda *xs: jnp.stack(xs), *[it["upload"] for it in items]
                ),
            }
        return tree

    def _acfg_fingerprint(self) -> dict:
        """Resolved async-only configuration, stamped into the checkpoint
        manifest so restore can reject a config-mismatched resume (which
        would silently break the bitwise-continuation contract); the
        availability model travels in the base ``_run_fingerprint``."""
        return {"buffer_size": self.buffer_size,
                "concurrency": self.concurrency,
                "n_pods": self.n_pods}

    def _ckpt_extra(self) -> dict:
        extra = super()._ckpt_extra()
        extra.update({"driver": "async", "n_pending": len(self._pending),
                      "n_buffer": len(self._buffer),
                      "async_cfg": self._acfg_fingerprint()})
        return extra

    def _upload_struct(self):
        """Upload-pytree structure via eval_shape (no FLOPs, no RNG use):
        needed to build restore templates for the stacked pending/buffer
        uploads, whose structure is method-defined (§2)."""
        throwaway = np.random.RandomState(0)
        bt = self.data.sample_round_batches(
            throwaway, np.asarray([0]), self.T, self.cfg.batch
        )
        bt = jax.tree.map(lambda x: jnp.asarray(x[0]), bt)
        proto_state = jax.tree.map(lambda x: x[0], self.client_states)
        method, loss_fn = self.method, self.loss_fn
        return jax.eval_shape(
            lambda s, b, batch: method.client_round(loss_fn, s, b, batch)[1],
            proto_state, self.broadcast, bt,
        )

    def restore(self, ckpt_dir=None, step=None) -> int:
        """Restore a checkpoint written by ``save`` (fresh, identically
        configured driver), including scheduler heap, in-flight results and
        the aggregation buffer; the resumed run continues the event loop
        bit-for-bit (tests/test_checkpoint_resume.py)."""
        ckpt_dir = ckpt_dir or self.cfg.ckpt_dir
        manifest = read_manifest(ckpt_dir, step)
        ex = manifest["extra"]
        if ex.get("driver") != "async":
            raise ValueError(
                f"checkpoint at {ckpt_dir} was written by the "
                f"{ex.get('driver')!r} driver, not 'async'"
            )
        self._check_run_fingerprint(ex, ckpt_dir)
        want = self._acfg_fingerprint()
        if ex.get("async_cfg") != want:
            raise ValueError(
                f"checkpoint at {ckpt_dir} was written with async config "
                f"{ex.get('async_cfg')}, but this driver resolved to {want}; "
                "resuming across a buffer_size/concurrency change is not "
                "a bitwise continuation"
            )
        tmpl = self._ckpt_template(bool(ex["n_pending"]), bool(ex["n_buffer"]))
        tree, extra = load_checkpoint(ckpt_dir, tmpl, step=manifest["step"])
        self._restore_core(tree, extra)
        self._load_store_shards(ckpt_dir, manifest["step"])
        self.scheduler.restore_state(tree["sched"])
        self._pending = {}
        if "pending" in tree:
            p = tree["pending"]
            losses = np.asarray(p["loss"], np.float32)
            versions = np.asarray(p["versions"], np.int64)
            for j, i in enumerate(np.asarray(p["ids"]).tolist()):
                self._pending[int(i)] = {
                    "state": jax.tree.map(lambda x: x[j], p["states"]),
                    "upload": jax.tree.map(lambda x: x[j], p["uploads"]),
                    "loss": losses[j],
                    "version": int(versions[j]),
                }
        self._buffer = []
        if "buffer" in tree:
            b = tree["buffer"]
            losses = np.asarray(b["loss"], np.float32)
            accs = np.asarray(b["acc"], np.float64)
            versions = np.asarray(b["versions"], np.int64)
            for j, i in enumerate(np.asarray(b["ids"]).tolist()):
                self._buffer.append({
                    "client": int(i),
                    "upload": jax.tree.map(lambda x: x[j], b["uploads"]),
                    "loss": losses[j],
                    "acc": accs[j],
                    "version": int(versions[j]),
                })
        return self._round

    def _ckpt_template(self, with_pending: bool = False, with_buffer: bool = False):
        tmpl = super()._ckpt_template()
        tmpl["sched"] = self.scheduler.state()
        if with_pending or with_buffer:
            upload = self._upload_struct()
            zero = np.zeros(0, np.int64)
            if with_pending:
                tmpl["pending"] = {
                    "ids": zero, "versions": zero,
                    "loss": np.zeros(0, np.float32),
                    "states": self.client_states,
                    "uploads": upload,
                }
            if with_buffer:
                tmpl["buffer"] = {
                    "ids": zero, "versions": zero,
                    "loss": np.zeros(0, np.float32),
                    "acc": np.zeros(0, np.float64),
                    "uploads": upload,
                }
        return tmpl
