"""ResNet-family CNN for the paper-faithful pFedSOP reproduction.

The paper trains ResNet-18 (CIFAR-10) / ResNet-9 (CIFAR-100, TinyImageNet)
with categorical cross-entropy.  BatchNorm is replaced with GroupNorm:
under vmap'd FL simulation, batch statistics leak across clients and are a
known confounder in FL reproductions (documented in DESIGN.md §8).

Pure JAX (lax.conv_general_dilated), params as nested dicts, f32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def _conv_init(key, kh, kw, cin, cout):
    fan_in = kh * kw * cin
    std = np.sqrt(2.0 / fan_in)
    return jax.random.normal(key, (kh, kw, cin, cout), jnp.float32) * std


def conv2d(x, w, stride=1):
    """x: (B,H,W,C), w: (kh,kw,Cin,Cout), SAME padding."""
    return jax.lax.conv_general_dilated(
        x, w,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def groupnorm(p, x, groups=8, eps=1e-5):
    b, h, w, c = x.shape
    g = min(groups, c)
    while c % g:
        g -= 1
    xg = x.reshape(b, h, w, g, c // g)
    mean = jnp.mean(xg, axis=(1, 2, 4), keepdims=True)
    var = jnp.var(xg, axis=(1, 2, 4), keepdims=True)
    xg = (xg - mean) * jax.lax.rsqrt(var + eps)
    x = xg.reshape(b, h, w, c)
    return x * p["scale"][None, None, None, :] + p["bias"][None, None, None, :]


def _gn_init(c):
    return {"scale": jnp.ones((c,), jnp.float32), "bias": jnp.zeros((c,), jnp.float32)}


def _block_init(key, cin, cout):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "conv1": _conv_init(k1, 3, 3, cin, cout),
        "gn1": _gn_init(cout),
        "conv2": _conv_init(k2, 3, 3, cout, cout),
        "gn2": _gn_init(cout),
    }
    if cin != cout:
        p["proj"] = _conv_init(k3, 1, 1, cin, cout)
    return p


def _block_apply(p, x, stride):
    h = jax.nn.relu(groupnorm(p["gn1"], conv2d(x, p["conv1"], stride)))
    h = groupnorm(p["gn2"], conv2d(h, p["conv2"]))
    if "proj" in p:
        x = conv2d(x, p["proj"], stride)
    elif stride != 1:
        x = x[:, ::stride, ::stride, :]
    return jax.nn.relu(h + x)


def init_params(key, cfg):
    """cfg: ModelConfig with cnn_channels / cnn_in_channels / n_classes."""
    chans = cfg.cnn_channels
    keys = jax.random.split(key, len(chans) + 2)
    params = {
        "stem": _conv_init(keys[0], 3, 3, cfg.cnn_in_channels, chans[0]),
        "stem_gn": _gn_init(chans[0]),
        "blocks": [],
    }
    cin = chans[0]
    for i, cout in enumerate(chans):
        params["blocks"].append(_block_init(keys[i + 1], cin, cout))
        cin = cout
    params["blocks"] = tuple(params["blocks"])
    params["fc_w"] = (
        jax.random.normal(keys[-1], (cin, cfg.n_classes), jnp.float32)
        / np.sqrt(cin)
    )
    params["fc_b"] = jnp.zeros((cfg.n_classes,), jnp.float32)
    return params


def apply(params, cfg, images):
    """images: (B,H,W,C) f32 -> logits (B, n_classes)."""
    x = jax.nn.relu(groupnorm(params["stem_gn"], conv2d(images, params["stem"])))
    for i, bp in enumerate(params["blocks"]):
        stride = 1 if i == 0 else 2
        x = _block_apply(bp, x, stride)
    x = jnp.mean(x, axis=(1, 2))  # global average pool
    return x @ params["fc_w"] + params["fc_b"]


def loss_fn(params, cfg, batch):
    """Categorical cross-entropy (the paper's probabilistic objective)."""
    logits = apply(params, cfg, batch["images"]).astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, batch["labels"][:, None], axis=-1)[:, 0]
    return jnp.mean(logz - gold)


def accuracy(params, cfg, batch):
    logits = apply(params, cfg, batch["images"])
    return jnp.mean((jnp.argmax(logits, -1) == batch["labels"]).astype(jnp.float32))
