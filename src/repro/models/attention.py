"""GQA attention: blockwise (flash-style) training/prefill path and a
single-token decode path with ring-buffer KV caches.

Supports: grouped-query heads, sliding windows, logit softcapping, optional
QK-norm, per-layer RoPE bases.  The blockwise scan keeps the materialised
score tensor at (B, q_block, H, S) instead of (B, S, H, S), which is what
makes 32k prefill fit in HBM; the Pallas kernel in repro/kernels/flash_gqa
is the TPU-tiled version of the same computation (tested against
repro/kernels/flash_gqa/ref.py which mirrors this math).

``ModelConfig.kernel_impl`` (DESIGN.md §9) selects the training/prefill
implementation: "reference" runs the blockwise scan below, kernel impls
dispatch ``attention_fwd`` to the fused Pallas kernel (window-pruned KV
grid for sliding-window layers).  The kernel path assumes the canonical
positions every model entry point passes (arange(S) per row — its
causality/window masks come from block indices); callers with exotic
position tensors must stay on the reference path.  Decode stays on the
jnp path: a single-token query against a ring-buffer cache is
gather/bandwidth bound, not a tiled-matmul shape.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.dispatch import resolve_impl
from repro.models.layers import dense_init, rmsnorm_init, rmsnorm, rope, softcap

NEG_INF = -1e30


def attn_init(key, cfg, dtype):
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "wq": dense_init(k1, (d, h, hd), d, dtype),
        "wk": dense_init(k2, (d, kv, hd), d, dtype),
        "wv": dense_init(k3, (d, kv, hd), d, dtype),
        "wo": dense_init(k4, (h, hd, d), h * hd, dtype, scale=1.0 / np.sqrt(2 * max(1, cfg.n_layers))),
    }
    if cfg.use_qk_norm:
        p["q_norm"] = rmsnorm_init(hd, dtype)
        p["k_norm"] = rmsnorm_init(hd, dtype)
    return p


def _project_qkv(p, cfg, x, positions, rope_base):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.use_qk_norm:
        impl = getattr(cfg, "kernel_impl", "reference")
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps, impl=impl)
        k = rmsnorm(p["k_norm"], k, cfg.norm_eps, impl=impl)
    q = rope(q, positions, rope_base)
    k = rope(k, positions, rope_base)
    return q, k, v


def _grouped_scores(q, k, cfg):
    """q: (B,Sq,H,hd), k: (B,Sk,KV,hd) -> scores (B,Sq,KV,G,Sk) in f32.

    Operands stay in their storage dtype (bf16) with f32 ACCUMULATION via
    preferred_element_type - the MXU-native mode.  An explicit .astype(f32)
    here would materialise an f32 copy of the whole KV cache in HBM
    (measured +12.8 GB/device at gemma2-9b decode_32k; EXPERIMENTS.md
    §Perf iteration 1).
    """
    b, sq, h, hd = q.shape
    kv = k.shape[2]
    g = h // kv
    qg = q.reshape(b, sq, kv, g, hd)
    s = jnp.einsum("bqkgd,btkd->bqkgt", qg, k,
                   preferred_element_type=jnp.float32)
    s = s * (hd**-0.5)
    if cfg.attn_softcap is not None:
        s = softcap(s, cfg.attn_softcap)
    return s


def attention_fwd(p, cfg, x, positions, window, rope_base, q_block=512):
    """Training / prefill self-attention (causal, optional sliding window).

    x: (B,S,D) already layer-normed;  positions: (B,S) int32.
    Scans over query blocks to bound live memory; kernel impls
    (``cfg.kernel_impl``) dispatch the same computation to the fused
    Pallas flash_gqa kernel instead.
    """
    b, s, d = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q, k, v = _project_qkv(p, cfg, x, positions, rope_base)

    impl = resolve_impl(getattr(cfg, "kernel_impl", "reference"), "flash_gqa")
    if impl != "reference":
        from repro.kernels.dispatch import kernel_scope
        from repro.kernels.flash_gqa.ops import flash_gqa

        with kernel_scope("flash_gqa", impl):
            # the resolved forward impl also selects the backward: kernel
            # forward -> fused flash backward kernel (same tiling/interpret
            # mode), so train steps never fall back to the scan-of-VJPs.
            o = flash_gqa(q, k, v, window=window, softcap=cfg.attn_softcap,
                          bq=q_block, bk=q_block,
                          interpret=impl == "kernel_interpret", bwd=impl)
        return jnp.einsum("bshk,hkd->bsd", o.astype(x.dtype), p["wo"])

    qb = min(q_block, s)
    while s % qb:
        qb //= 2
    nb = s // qb

    # (nb, B, qb, H, hd) query blocks; keys/values stay whole.
    q_blocks = jnp.moveaxis(q.reshape(b, nb, qb, h, hd), 1, 0)
    pos_blocks = jnp.moveaxis(positions.reshape(b, nb, qb), 1, 0)
    kpos = positions  # (B,S)

    def block(carry, inp):
        qi, qpos = inp  # (B,qb,H,hd), (B,qb)
        sc = _grouped_scores(qi, k, cfg)  # (B,qb,KV,G,S)
        mask = kpos[:, None, :] <= qpos[:, :, None]  # causal (B,qb,S)
        if window is not None:
            mask &= (qpos[:, :, None] - kpos[:, None, :]) < window
        sc = jnp.where(mask[:, :, None, None, :], sc, NEG_INF)
        w = jax.nn.softmax(sc, axis=-1)
        g = h // kv
        # probabilities cast to the storage dtype for the PV matmul
        # (standard flash practice); accumulation stays f32 on the MXU
        o = jnp.einsum("bqkgt,btkd->bqkgd", w.astype(v.dtype), v,
                       preferred_element_type=jnp.float32)
        return carry, o.reshape(b, qb, h, hd).astype(x.dtype)

    _, o_blocks = jax.lax.scan(block, None, (q_blocks, pos_blocks))
    o = jnp.moveaxis(o_blocks, 0, 1).reshape(b, s, h, hd)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"])


# ---------------------------------------------------------------------------
# Decode path (single new token against a KV cache)
# ---------------------------------------------------------------------------


def init_cache(cfg, batch, capacity, dtype):
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    if cfg.kv_quant:
        # int8 symmetric per-(token, kv-head) quantisation: halves cache
        # HBM vs bf16 (the musicgen-large decode_32k cache is 1.6 TB)
        return {
            "k": jnp.zeros((batch, capacity, kv, hd), jnp.int8),
            "v": jnp.zeros((batch, capacity, kv, hd), jnp.int8),
            "k_scale": jnp.zeros((batch, capacity, kv), jnp.bfloat16),
            "v_scale": jnp.zeros((batch, capacity, kv), jnp.bfloat16),
            "pos": jnp.full((capacity,), -1, jnp.int32),
        }
    return {
        "k": jnp.zeros((batch, capacity, kv, hd), dtype),
        "v": jnp.zeros((batch, capacity, kv, hd), dtype),
        "pos": jnp.full((capacity,), -1, jnp.int32),  # absolute position per slot
    }


def _quantize(x):
    """x: (..., hd) -> (int8 values, bf16 scale over the last dim)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax / 127.0, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]), -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.bfloat16)


def attention_decode(p, cfg, x, pos, cache, window, rope_base):
    """Decode one token.

    x: (B,1,D) normed hidden;  pos: scalar int32 absolute position;
    cache: ring buffer dict (capacity W for windowed layers, seq_len for full).
    Returns (out (B,1,D), new_cache).
    """
    b = x.shape[0]
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    positions = jnp.full((b, 1), pos, jnp.int32)
    q, k_new, v_new = _project_qkv(p, cfg, x, positions, rope_base)

    cap = cache["k"].shape[1]
    slot = pos % cap
    slot_pos = jax.lax.dynamic_update_slice(cache["pos"], pos[None].astype(jnp.int32), (slot,))
    if "k_scale" in cache:  # int8 cache: quantise the new token on write
        kq, ks = _quantize(k_new)
        vq, vs = _quantize(v_new)
        kc = jax.lax.dynamic_update_slice(cache["k"], kq, (0, slot, 0, 0))
        vc = jax.lax.dynamic_update_slice(cache["v"], vq, (0, slot, 0, 0))
        kss = jax.lax.dynamic_update_slice(cache["k_scale"], ks, (0, slot, 0))
        vss = jax.lax.dynamic_update_slice(cache["v_scale"], vs, (0, slot, 0))
        new_cache = {"k": kc, "v": vc, "k_scale": kss, "v_scale": vss, "pos": slot_pos}
        # dequantised views feed the score/PV einsums; the convert+scale
        # fuses into the dot's operand fetch (no materialised copy)
        k = kc.astype(x.dtype) * kss[..., None].astype(x.dtype)
        v = vc.astype(x.dtype) * vss[..., None].astype(x.dtype)
    else:
        k = jax.lax.dynamic_update_slice(cache["k"], k_new, (0, slot, 0, 0))
        v = jax.lax.dynamic_update_slice(cache["v"], v_new, (0, slot, 0, 0))
        new_cache = {"k": k, "v": v, "pos": slot_pos}

    sc = _grouped_scores(q, k, cfg)  # (B,1,KV,G,cap)
    valid = (slot_pos >= 0) & (slot_pos <= pos)
    if window is not None:
        valid &= (pos - slot_pos) < window
    sc = jnp.where(valid[None, None, None, None, :], sc, NEG_INF)
    w = jax.nn.softmax(sc, axis=-1)
    o = jnp.einsum("bqkgt,btkd->bqkgd", w.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    o = o.reshape(b, 1, h, hd).astype(x.dtype)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"]), new_cache


def pack_prefill_cache(cfg, k, v, positions, capacity, dtype):
    """Turn full-sequence post-RoPE k/v (B,S,KV,hd) into the ring-buffer
    cache decode expects: slot(p) = p % capacity, keeping the last
    ``capacity`` positions (all of them when capacity == S)."""
    b, s = k.shape[0], k.shape[1]
    cap = capacity or s  # allocated capacity (>= s for full-attention layers
    #                      so later decode positions don't wrap onto the prompt)
    take = min(cap, s)
    last_pos = positions[0, -take:]  # (take,) absolute positions
    slots = last_pos % cap
    kk, vv = k[:, -take:], v[:, -take:]
    if cfg.kv_quant:
        kq, ks = _quantize(kk)
        vq, vs = _quantize(vv)
        cache = {
            "k": jnp.zeros((b, cap, cfg.n_kv_heads, cfg.head_dim), jnp.int8
                           ).at[:, slots].set(kq),
            "v": jnp.zeros((b, cap, cfg.n_kv_heads, cfg.head_dim), jnp.int8
                           ).at[:, slots].set(vq),
            "k_scale": jnp.zeros((b, cap, cfg.n_kv_heads), jnp.bfloat16
                                 ).at[:, slots].set(ks),
            "v_scale": jnp.zeros((b, cap, cfg.n_kv_heads), jnp.bfloat16
                                 ).at[:, slots].set(vs),
            "pos": jnp.full((cap,), -1, jnp.int32).at[slots].set(last_pos.astype(jnp.int32)),
        }
        return cache
    return {
        "k": jnp.zeros((b, cap, cfg.n_kv_heads, cfg.head_dim), dtype).at[:, slots].set(kk.astype(dtype)),
        "v": jnp.zeros((b, cap, cfg.n_kv_heads, cfg.head_dim), dtype).at[:, slots].set(vv.astype(dtype)),
        "pos": jnp.full((cap,), -1, jnp.int32).at[slots].set(last_pos.astype(jnp.int32)),
    }
