"""Mixture-of-experts FFN block (OLMoE / granite-MoE style).

Two interchangeable implementations, selected by ``impl``:

``dense``    - every expert processes every token; router weights zero out the
               non-selected experts.  Compute-wasteful by a factor E/k but
               trivially shardable (experts on the `model` axis) and has no
               load-balance pathologies.  This is the BASELINE the roofline
               table exposes (MODEL_FLOPS/HLO_FLOPs ratio collapses).
``dispatch`` - capacity-based dispatch: tokens are scattered into an
               (E, capacity, D) buffer (scatter/gather indexing, NOT the
               GShard one-hot matmul whose (N*k, E, cap) mask tensor is
               infeasible at 1M-token batches), each expert runs a dense FFN
               over its buffer, results are gathered back and combined with
               the router probabilities.  top-k active FLOPs only
               (+ capacity padding).  Overflowing tokens are dropped for
               that expert (standard GShard semantics).  This is the
               beyond-paper hillclimb lever for the MoE archs.

Router: linear -> top-k -> softmax over the selected logits (OLMoE
normalizes after selection).  An auxiliary load-balance loss (Switch eq. 4)
is returned for the training objective.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import dense_init


def moe_init(key, cfg, dtype):
    d, e, f = cfg.d_model, cfg.n_experts, cfg.expert_ff
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "router": dense_init(k1, (d, e), d, jnp.float32),  # router math in f32
        "wi_gate": dense_init(k2, (e, d, f), d, dtype),
        "wi_up": dense_init(k3, (e, d, f), d, dtype),
        "wo": dense_init(k4, (e, f, d), f, dtype, scale=1.0 / np.sqrt(2 * max(1, cfg.n_layers))),
    }


def _router(p, cfg, x):
    """Returns (weights (N,E) f32 with zeros at non-selected, aux_loss)."""
    n, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    logits = x.astype(jnp.float32) @ p["router"]  # (N, E)
    top_vals, top_idx = jax.lax.top_k(logits, k)  # (N, k)
    top_w = jax.nn.softmax(top_vals, axis=-1)  # normalize over selected
    # scatter back to dense (N, E): one-hot combine
    onehot = jax.nn.one_hot(top_idx, e, dtype=jnp.float32)  # (N, k, E)
    weights = jnp.einsum("nk,nke->ne", top_w, onehot)
    # Switch-style load-balance aux loss: E * sum_e f_e * P_e
    probs = jax.nn.softmax(logits, axis=-1)
    frac_tokens = jnp.mean(jnp.sum(onehot, axis=1), axis=0)  # f_e
    frac_prob = jnp.mean(probs, axis=0)  # P_e
    aux = e * jnp.sum(frac_tokens * frac_prob)
    return weights, top_idx, top_w, aux


def _expert_ffn(p, xs):
    """xs: (E, C, D) -> (E, C, D); batched SwiGLU over the expert axis."""
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xs, p["wi_gate"]))
    u = jnp.einsum("ecd,edf->ecf", xs, p["wi_up"])
    return jnp.einsum("ecf,efd->ecd", g * u, p["wo"])


def moe_dense(p, cfg, x):
    """Baseline: all experts on all tokens.  x: (B,S,D) -> (B,S,D)."""
    b, s, d = x.shape
    xf = x.reshape(b * s, d)
    weights, _, _, aux = _router(p, cfg, xf)
    g = jax.nn.silu(jnp.einsum("nd,edf->enf", xf, p["wi_gate"]))
    u = jnp.einsum("nd,edf->enf", xf, p["wi_up"])
    y = jnp.einsum("enf,efd->end", g * u, p["wo"])  # (E, N, D)
    out = jnp.einsum("end,ne->nd", y.astype(jnp.float32), weights)
    return out.reshape(b, s, d).astype(x.dtype), aux


def moe_dispatch(p, cfg, x):
    """Capacity-based scatter/gather dispatch.  x: (B,S,D) -> (B,S,D).

    capacity = ceil(N * top_k / E * capacity_factor), rounded up to a
    multiple of 8 (TPU sublane).  Overflowing tokens are dropped (their
    contribution for that expert is zero) - standard GShard semantics.
    """
    b, s, d = x.shape
    n = b * s
    e, k = cfg.n_experts, cfg.top_k
    xf = x.reshape(n, d)
    weights, top_idx, top_w, aux = _router(p, cfg, xf)
    del weights

    cap = int(np.ceil(n * k / e * cfg.capacity_factor))
    cap = max(8, int(np.ceil(cap / 8) * 8))

    # position of each (token, slot) within its expert's buffer: running
    # count of prior slots routed to the same expert, in token order.
    expert_of = top_idx.reshape(n * k)  # (T,) T = N*k slots
    onehot = jax.nn.one_hot(expert_of, e, dtype=jnp.int32)  # (T, E)
    pos = jnp.sum(jnp.cumsum(onehot, axis=0) * onehot, axis=-1) - 1  # (T,)
    keep = pos < cap
    pos_c = jnp.where(keep, pos, cap - 1)  # clamped; dropped slots masked out

    token_of = jnp.arange(n * k) // k
    contrib = xf[token_of] * keep[:, None].astype(xf.dtype)  # (T, D)
    xs = jnp.zeros((e, cap, d), xf.dtype).at[expert_of, pos_c].add(
        contrib, mode="drop", unique_indices=False
    )

    ys = _expert_ffn(p, xs)  # (E, cap, D)

    back = ys[expert_of, pos_c]  # (T, D)
    comb_w = top_w.reshape(n * k) * keep.astype(jnp.float32)
    out = jnp.sum(
        (back.astype(jnp.float32) * comb_w[:, None]).reshape(n, k, d), axis=1
    )
    return out.reshape(b, s, d).astype(x.dtype), aux


def _positions_sorted(expert_of, e):
    """Position of each slot within its expert's buffer, via stable sort.

    expert_of: (T,) int32 -> (T,) int32 positions.  O(T log T) - replaces
    the (T, E) one-hot cumsum whose reduce-window lowering is costed
    quadratically by XLA (measured +1.6 s compute on olmoe train_4k;
    EXPERIMENTS.md §Perf).
    """
    t = expert_of.shape[0]
    order = jnp.argsort(expert_of, stable=True)  # slots grouped by expert
    sorted_e = expert_of[order]
    # index of the first slot of each expert's run
    run_start = jnp.searchsorted(sorted_e, jnp.arange(e), side="left")
    pos_sorted = jnp.arange(t, dtype=jnp.int32) - run_start[sorted_e].astype(jnp.int32)
    # scatter back to original slot order
    return jnp.zeros((t,), jnp.int32).at[order].set(pos_sorted)


def moe_dispatch_grouped(p, cfg, x):
    """Group-local capacity dispatch (GShard-style groups).

    The flat ``moe_dispatch`` computes token positions with a GLOBAL cumsum
    over all N*k slots and scatters into a globally-indexed (E, cap, D)
    buffer - under expert sharding GSPMD can only realise that scatter by
    replicating the token tensor (measured: collective term 2.0 -> 15.2 s
    on olmoe train_4k; EXPERIMENTS.md §Perf).  Here every batch row is its
    own routing group: position math (cumsum, one-hot) is group-local so
    it partitions cleanly over ``data``; the only cross-mesh movement is
    the compact (G, E, cap_g, D) expert buffer entering the einsum with
    the E-sharded expert weights (an all-to-all of ~N*k/E*capf tokens -
    6.4x smaller than the dense-all intermediates it replaces).

    Per-group capacity cap_g = ceil(n_g * k / E * capacity_factor)
    (standard GShard semantics: overflow dropped per group).
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    n = b * s
    xf = x.reshape(n, d)
    _, top_idx, top_w, aux = _router(p, cfg, xf)

    g = b  # one group per batch row
    n_g = s
    cap = int(np.ceil(n_g * k / e * cfg.capacity_factor))
    cap = max(8, int(np.ceil(cap / 8) * 8))

    expert_of = top_idx.reshape(g, n_g * k)  # (G, T_g)
    pos = jax.vmap(lambda eo: _positions_sorted(eo, e))(expert_of)  # (G, T_g)
    keep = pos < cap
    pos_c = jnp.where(keep, pos, cap - 1)

    xg = x  # (G, n_g, D)
    token_of = jnp.arange(n_g * k) // k  # local token index within group
    contrib = xg[:, token_of, :] * keep[..., None].astype(x.dtype)  # (G, T_g, D)
    xs = jnp.zeros((g, e, cap, d), x.dtype).at[
        jnp.arange(g)[:, None], expert_of, pos_c
    ].add(contrib, mode="drop")

    # expert FFN over the grouped buffer; E sharded -> all-to-all on xs
    gg = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xs, p["wi_gate"]))
    uu = jnp.einsum("gecd,edf->gecf", xs, p["wi_up"])
    ys = jnp.einsum("gecf,efd->gecd", gg * uu, p["wo"])  # (G, E, cap, D)

    back = ys[jnp.arange(g)[:, None], expert_of, pos_c]  # (G, T_g, D)
    comb_w = top_w.reshape(g, n_g * k) * keep.astype(jnp.float32)
    out = jnp.sum(
        (back.astype(jnp.float32) * comb_w[..., None]).reshape(g, n_g, k, d), axis=2
    )
    return out.astype(x.dtype), aux


def moe_ffn(p, cfg, x, impl: str = "dense"):
    if impl == "dense":
        return moe_dense(p, cfg, x)
    if impl == "dispatch":
        return moe_dispatch(p, cfg, x)
    if impl == "dispatch_grouped":
        return moe_dispatch_grouped(p, cfg, x)
    raise ValueError(f"unknown moe impl {impl!r}")
