"""Shared neural-net building blocks (pure JAX, no flax).

Parameters are plain nested dicts of jnp arrays.  Initialisers take an
explicit PRNG key.  All blocks are written to be shardable under pjit: no
data-dependent shapes, reductions in f32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.dispatch import resolve_impl


def dense_init(key, shape, in_axis_size, dtype, scale=1.0):
    """Variance-scaling (fan-in) normal init."""
    std = scale / np.sqrt(max(1, in_axis_size))
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------


def rmsnorm_init(d, dtype):
    return {"scale": jnp.zeros((d,), dtype)}  # (1 + scale) parametrisation


def rmsnorm(p, x, eps=1e-6, impl="reference"):
    """(1 + scale)-parametrised RMSNorm, f32 reduce.

    ``impl`` is the model-level kernel policy (``ModelConfig.kernel_impl``,
    DESIGN.md §9), resolved host-side: "reference" runs the plain-jnp math
    below, kernel impls dispatch to the fused Pallas kernel
    (repro.kernels.rmsnorm) — same math, same (1 + scale) parametrisation,
    one VMEM pass.
    """
    impl = resolve_impl(impl, "rmsnorm")
    if impl != "reference":
        from repro.kernels.dispatch import kernel_scope
        from repro.kernels.rmsnorm.ops import rmsnorm as rmsnorm_kernel

        with kernel_scope("rmsnorm", impl):
            return rmsnorm_kernel(x, p["scale"], eps=eps,
                                  interpret=impl == "kernel_interpret")
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + p["scale"].astype(jnp.float32))).astype(dtype)


def rmsnorm_noscale(x, eps=1e-6):
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope(x, positions, base):
    """Apply rotary embeddings.

    x: (..., S, H, hd) with hd even; positions: (..., S) int32.
    """
    hd = x.shape[-1]
    assert hd % 2 == 0, "head_dim must be even for RoPE"
    half = hd // 2
    freqs = base ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    # angles: (..., S, half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    sin = jnp.sin(ang)[..., None, :]  # broadcast over heads
    cos = jnp.cos(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Gated MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------


def mlp_init(key, d_model, d_ff, n_layers, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi_gate": dense_init(k1, (d_model, d_ff), d_model, dtype),
        "wi_up": dense_init(k2, (d_model, d_ff), d_model, dtype),
        "wo": dense_init(k3, (d_ff, d_model), d_ff, dtype, scale=1.0 / np.sqrt(2 * max(1, n_layers))),
    }


def mlp(p, x, activation="silu"):
    act = jax.nn.silu if activation == "silu" else jax.nn.gelu
    g = act(x @ p["wi_gate"])
    u = x @ p["wi_up"]
    return (g * u) @ p["wo"]


def softcap(x, cap):
    return cap * jnp.tanh(x / cap)
