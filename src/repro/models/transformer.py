"""Unified decoder-stack model covering all assigned architecture families.

One parameter/init/apply codepath serves dense (gemma/granite), MoE (olmoe,
granite-moe), SSM (mamba2), hybrid (zamba2), VLM (internvl2) and audio
(musicgen).  The layer schedule comes from ``cfg.pattern * n_rep + tail``;
the pattern repetitions run under ``jax.lax.scan`` with parameters stacked
on a leading ``n_rep`` axis so compile time and HLO size stay flat in depth
(critical for the 64-layer mamba2 dry-run).

`shared_attn` sublayers (Zamba2) hold ONE parameter set outside the scan -
captured by closure, broadcast into every repetition - while their KV caches
are per-repetition (stacked like everything else).

Modality frontends per the carve-out:
  vision_stub      batch["patch_embeds"] (B, n_patches, d_vision) projected
                   and prepended to the token embeddings.
  audio_codebooks  batch["tokens"] (B, K_cb, S): per-codebook embeddings are
                   summed; the LM head has one output head per codebook.

Params are plain nested dicts; embedding is tied to the LM head (logits =
x @ embed.T), except audio which has per-codebook heads.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (
    dense_init,
    embed_init,
    mlp,
    mlp_init,
    rmsnorm,
    rmsnorm_init,
    softcap,
)

AUX_LOSS_COEF = 0.01  # MoE load-balance coefficient (Switch / OLMoE default)


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


def _norm(p, cfg, x):
    """Every stack norm routes through the model-level kernel policy
    (``cfg.kernel_impl``, DESIGN.md §9) — one helper instead of per-call
    plumbing at the 12 ln1/ln2/final_norm sites."""
    return rmsnorm(p, x, cfg.norm_eps, impl=getattr(cfg, "kernel_impl", "reference"))


# ---------------------------------------------------------------------------
# Long-context variant (the one documented carve-in for dense archs)
# ---------------------------------------------------------------------------


def apply_long_context(cfg):
    """For ``long_500k`` on window-mode archs: cap every attention window.

    SSM/hybrid archs (long_context_mode="native") are returned unchanged -
    their recurrence is already O(1) in context.
    """
    if cfg.long_context_mode != "window":
        return cfg
    w = cfg.long_context_window

    def capw(spec):
        if spec.kind in ("attn", "moe", "shared_attn"):
            return spec.replace(window=w if spec.window is None else min(spec.window, w))
        return spec

    return cfg.replace(
        pattern=tuple(capw(s) for s in cfg.pattern),
        tail=tuple(capw(s) for s in cfg.tail),
    )


# ---------------------------------------------------------------------------
# Block init / apply (one sublayer of the schedule)
# ---------------------------------------------------------------------------


def _block_init(key, spec, cfg, dtype):
    if spec.kind == "ssm":
        k1 = jax.random.fold_in(key, 1)
        return {"ln1": rmsnorm_init(cfg.d_model, dtype), "ssm": ssm_mod.ssm_init(k1, cfg, dtype)}
    k1, k2 = jax.random.split(key)
    p = {
        "ln1": rmsnorm_init(cfg.d_model, dtype),
        "attn": attn_mod.attn_init(k1, cfg, dtype),
        "ln2": rmsnorm_init(cfg.d_model, dtype),
    }
    if spec.kind == "moe":
        p["moe"] = moe_mod.moe_init(k2, cfg, dtype)
    else:  # attn / shared_attn
        p["mlp"] = mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.n_layers, dtype)
    return p


def _block_fwd(p, spec, cfg, x, positions):
    """Full-sequence (train/prefill) sublayer.  Returns (x, aux_loss)."""
    aux = jnp.float32(0.0)
    if spec.kind == "ssm":
        return x + ssm_mod.ssm_forward(p["ssm"], cfg, _norm(p["ln1"], cfg, x)), aux
    h = _norm(p["ln1"], cfg, x)
    x = x + attn_mod.attention_fwd(p["attn"], cfg, h, positions, spec.window,
                                   spec.rope_base, q_block=cfg.attn_q_block)
    h = _norm(p["ln2"], cfg, x)
    if spec.kind == "moe":
        y, aux = moe_mod.moe_ffn(p["moe"], cfg, h, getattr(cfg, "moe_impl", "dense"))
        return x + y, aux
    return x + mlp(p["mlp"], h), aux


def _block_decode(p, spec, cfg, x, pos, cache):
    """Single-token sublayer.  Returns (x, new_cache)."""
    if spec.kind == "ssm":
        y, new_cache = ssm_mod.ssm_decode(p["ssm"], cfg, _norm(p["ln1"], cfg, x), cache)
        return x + y, new_cache
    h = _norm(p["ln1"], cfg, x)
    y, new_cache = attn_mod.attention_decode(p["attn"], cfg, h, pos, cache, spec.window, spec.rope_base)
    x = x + y
    h = _norm(p["ln2"], cfg, x)
    if spec.kind == "moe":
        y, _ = moe_mod.moe_ffn(p["moe"], cfg, h, getattr(cfg, "moe_impl", "dense"))
        return x + y, new_cache
    return x + mlp(p["mlp"], h), new_cache


def _block_cache_init(spec, cfg, batch, seq_len, dtype):
    if spec.kind == "ssm":
        return ssm_mod.ssm_init_cache(cfg, batch, dtype)
    cap = seq_len if spec.window is None else min(spec.window, seq_len)
    return attn_mod.init_cache(cfg, batch, cap, dtype)


# ---------------------------------------------------------------------------
# Whole-model init
# ---------------------------------------------------------------------------


def init_params(key, cfg):
    dtype = _dtype(cfg)
    keys = jax.random.split(key, 8)
    params: dict[str, Any] = {}

    if cfg.frontend == "audio_codebooks":
        params["embed"] = embed_init(keys[0], (cfg.n_codebooks, cfg.vocab_size, cfg.d_model), dtype)
        params["heads"] = dense_init(keys[5], (cfg.n_codebooks, cfg.d_model, cfg.vocab_size), cfg.d_model, dtype)
    else:
        params["embed"] = embed_init(keys[0], (cfg.vocab_size, cfg.d_model), dtype)
    if cfg.frontend == "vision_stub":
        params["vis_proj"] = dense_init(keys[1], (cfg.d_vision, cfg.d_model), cfg.d_vision, dtype)

    has_shared = any(s.kind == "shared_attn" for s in cfg.layers)
    if has_shared:
        shared_spec = next(s for s in cfg.layers if s.kind == "shared_attn")
        params["shared"] = _block_init(keys[2], shared_spec, cfg, dtype)

    if cfg.pattern and cfg.n_rep:
        rep_keys = jax.random.split(keys[3], cfg.n_rep)

        def one_rep(k):
            ks = jax.random.split(k, len(cfg.pattern))
            return tuple(
                {} if s.kind == "shared_attn" else _block_init(ks[j], s, cfg, dtype)
                for j, s in enumerate(cfg.pattern)
            )

        params["pattern"] = jax.vmap(one_rep)(rep_keys)
    if cfg.tail:
        tkeys = jax.random.split(keys[4], len(cfg.tail))
        params["tail"] = tuple(
            _block_init(tkeys[j], s, cfg, dtype) for j, s in enumerate(cfg.tail)
        )

    params["final_norm"] = rmsnorm_init(cfg.d_model, dtype)
    return params


# ---------------------------------------------------------------------------
# Embedding frontends
# ---------------------------------------------------------------------------


def embed_inputs(params, cfg, batch):
    """Returns (x (B,S,D), positions (B,S))."""
    scale = jnp.asarray(np.sqrt(cfg.d_model), _dtype(cfg))
    if cfg.frontend == "audio_codebooks":
        toks = batch["tokens"]  # (B, K_cb, S)
        x = sum(
            jnp.take(params["embed"][k], toks[:, k], axis=0)
            for k in range(cfg.n_codebooks)
        )
        x = x * scale
        b, s = toks.shape[0], toks.shape[2]
        pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
        return x, pos
    if cfg.frontend == "vision_stub":
        patches = batch["patch_embeds"].astype(_dtype(cfg)) @ params["vis_proj"]
        toks = batch["tokens"]
        text = jnp.take(params["embed"], toks, axis=0) * scale
        x = jnp.concatenate([patches, text], axis=1)
        b, s = x.shape[0], x.shape[1]
        pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
        return x, pos
    toks = batch["tokens"]
    x = jnp.take(params["embed"], toks, axis=0) * scale
    b, s = toks.shape
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    return x, pos


def lm_logits(params, cfg, x):
    """Tied LM head; audio gets per-codebook heads -> (B,S,K,V)."""
    if cfg.frontend == "audio_codebooks":
        return jnp.einsum("bsd,kdv->bskv", x, params["heads"])
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
    if cfg.final_softcap is not None:
        logits = softcap(logits.astype(jnp.float32), cfg.final_softcap)
    return logits


# ---------------------------------------------------------------------------
# Full-sequence forward (training / prefill)
# ---------------------------------------------------------------------------


def forward(params, cfg, batch):
    """Returns (hidden (B,S,D), aux_loss scalar)."""
    x, positions = embed_inputs(params, cfg, batch)
    aux_total = jnp.float32(0.0)
    remat = cfg.remat == "block"

    def pin(x):
        """Sequence-parallel residual-stream constraint (launch-only)."""
        if not cfg.seq_shard:
            return x
        from jax.sharding import PartitionSpec as P

        return jax.lax.with_sharding_constraint(x, P("data", "model", None))

    x = pin(x)

    def apply_block(p, spec, x):
        fn = _block_fwd
        if remat:
            # backward recomputes attention probabilities / FFN intermediates
            # instead of saving them (needed to fit v5e HBM at train_4k;
            # prevent_cse=False is the recommended setting under scan)
            fn = jax.checkpoint(_block_fwd, static_argnums=(1, 2), prevent_cse=False)
        return fn(p, spec, cfg, x, positions)

    if cfg.pattern and cfg.n_rep:
        shared = params.get("shared")

        def rep_body(carry, rep_params):
            x, aux = carry
            for j, spec in enumerate(cfg.pattern):
                p = shared if spec.kind == "shared_attn" else rep_params[j]
                x, a = apply_block(p, spec, x)
                x = pin(x)
                aux = aux + a
            return (x, aux), None

        (x, aux_total), _ = jax.lax.scan(rep_body, (x, aux_total), params["pattern"])

    for j, spec in enumerate(cfg.tail):
        p = params.get("shared") if spec.kind == "shared_attn" else params["tail"][j]
        x, a = apply_block(p, spec, x)
        x = pin(x)
        aux_total = aux_total + a

    x = _norm(params["final_norm"], cfg, x)
    return x, aux_total


def cross_entropy(logits, labels, mask=None):
    """Mean CE in f32.  logits (..., V), labels (...) int32."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def lm_loss(params, cfg, batch):
    """Next-token CE (+ MoE aux).  batch["labels"] aligned with positions."""
    hidden, aux = forward(params, cfg, batch)
    if cfg.frontend == "vision_stub":
        # loss only over the text region (patches carry no labels)
        hidden = hidden[:, cfg.n_patches :, :]
    logits = lm_logits(params, cfg, hidden)
    if cfg.frontend == "audio_codebooks":
        labels = batch["labels"]  # (B, K, S)
        loss = cross_entropy(logits, jnp.moveaxis(labels, 1, 2))
    else:
        loss = cross_entropy(logits, batch["labels"])
    return loss + AUX_LOSS_COEF * aux


# ---------------------------------------------------------------------------
# Decode (single new token against caches)
# ---------------------------------------------------------------------------


def init_caches(cfg, batch, seq_len):
    dtype = _dtype(cfg)
    caches: dict[str, Any] = {}
    if cfg.pattern and cfg.n_rep:

        def one_rep(_):
            return tuple(
                _block_cache_init(s, cfg, batch, seq_len, dtype) for s in cfg.pattern
            )

        caches["pattern"] = jax.vmap(one_rep)(jnp.arange(cfg.n_rep))
    if cfg.tail:
        caches["tail"] = tuple(
            _block_cache_init(s, cfg, batch, seq_len, dtype) for s in cfg.tail
        )
    return caches


def decode_step(params, cfg, batch, pos, caches):
    """One token for every sequence in the batch.

    batch supplies the current token(s); pos is the scalar absolute position.
    Returns (logits (B,1,V...), new caches).
    """
    x, _ = embed_inputs(params, cfg, batch)  # (B,1,D)
    shared = params.get("shared")
    new_caches: dict[str, Any] = {}

    if cfg.pattern and cfg.n_rep:

        def rep_body(x, inp):
            rep_params, rep_cache = inp
            new_cache = []
            for j, spec in enumerate(cfg.pattern):
                p = shared if spec.kind == "shared_attn" else rep_params[j]
                x, c = _block_decode(p, spec, cfg, x, pos, rep_cache[j])
                new_cache.append(c)
            return x, tuple(new_cache)

        x, new_caches["pattern"] = jax.lax.scan(
            rep_body, x, (params["pattern"], caches["pattern"])
        )

    if cfg.tail:
        tail_caches = []
        for j, spec in enumerate(cfg.tail):
            p = shared if spec.kind == "shared_attn" else params["tail"][j]
            x, c = _block_decode(p, spec, cfg, x, pos, caches["tail"][j])
            tail_caches.append(c)
        new_caches["tail"] = tuple(tail_caches)

    x = _norm(params["final_norm"], cfg, x)
    return lm_logits(params, cfg, x), new_caches


# ---------------------------------------------------------------------------
# Prefill -> decode cache handoff (serving path)
# ---------------------------------------------------------------------------


def _block_prefill(p, spec, cfg, x, positions, capacity):
    """Sublayer forward that ALSO builds the decode cache it leaves behind.

    ``capacity``: total sequence budget (prompt + planned decode steps);
    full-attention layers allocate it outright, windowed layers allocate
    min(window, capacity).
    """
    if spec.kind == "ssm":
        y, cache = ssm_mod.ssm_forward_with_cache(
            p["ssm"], cfg, _norm(p["ln1"], cfg, x))
        return x + y, cache
    h = _norm(p["ln1"], cfg, x)
    q, k, v = attn_mod._project_qkv(p["attn"], cfg, h, positions, spec.rope_base)
    cap = capacity if spec.window is None else min(spec.window, capacity)
    cache = attn_mod.pack_prefill_cache(cfg, k, v, positions, cap, _dtype(cfg))
    # reuse the blockwise attention for the actual mixing
    y = attn_mod.attention_fwd(p["attn"], cfg, h, positions, spec.window,
                               spec.rope_base, q_block=cfg.attn_q_block)
    x = x + y
    h = _norm(p["ln2"], cfg, x)
    if spec.kind == "moe":
        y, _ = moe_mod.moe_ffn(p["moe"], cfg, h, getattr(cfg, "moe_impl", "dense"))
        return x + y, cache
    return x + mlp(p["mlp"], h), cache


def prefill_with_caches(params, cfg, batch, capacity=None):
    """Full prompt forward returning (last-token logits, decode caches).

    ``capacity``: total sequence budget (prompt + decode steps; defaults
    to prompt_len + 64).  The caches match ``init_caches(cfg, B, capacity)``
    structure exactly, so ``decode_step(params, cfg, next_tok, pos=S,
    caches)`` continues the sequence (tests/test_models.py verifies the
    logits equal a full forward).
    """
    x, positions = embed_inputs(params, cfg, batch)
    seq_len = capacity or (x.shape[1] + 64)
    shared = params.get("shared")
    caches: dict[str, Any] = {}

    if cfg.pattern and cfg.n_rep:

        def rep_body(x, rep_params):
            new_caches = []
            for j, spec in enumerate(cfg.pattern):
                p = shared if spec.kind == "shared_attn" else rep_params[j]
                x, c = _block_prefill(p, spec, cfg, x, positions, seq_len)
                new_caches.append(c)
            return x, tuple(new_caches)

        x, caches["pattern"] = jax.lax.scan(rep_body, x, params["pattern"])

    if cfg.tail:
        tail_caches = []
        for j, spec in enumerate(cfg.tail):
            p = shared if spec.kind == "shared_attn" else params["tail"][j]
            x, c = _block_prefill(p, spec, cfg, x, positions, seq_len)
            tail_caches.append(c)
        caches["tail"] = tuple(tail_caches)

    x = _norm(params["final_norm"], cfg, x)
    return lm_logits(params, cfg, x[:, -1:, :]), caches
