"""Model zoo: unified decoder stack (all assigned archs) + ResNet CNN."""
from repro.models import transformer, cnn  # noqa: F401
