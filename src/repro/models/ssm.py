"""Mamba2 (SSD / state-space duality) mixer block.  arXiv:2405.21060.

Training/prefill uses the chunked SSD algorithm: within a chunk the output
is a (masked, decay-weighted) attention-like matmul - MXU-friendly; across
chunks a constant-size recurrent state (B, H, P, N) is carried by
``lax.scan``.  Decode is the pure recurrence: O(1) in sequence length, which
is what makes the ``long_500k`` shape native for the SSM/hybrid archs.

Shapes:  d_inner = expand * d_model,  H = d_inner // head_dim (P),
N = ssm_state,  G = 1 group (B/C shared across heads, Mamba2 default).

All decay/softmax-free accumulation is f32; parameters and activations keep
the configured dtype.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import dense_init, rmsnorm_noscale


def ssm_dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = d_inner // cfg.ssm_head_dim
    return d_inner, n_heads


def ssm_init(key, cfg, dtype):
    d = cfg.d_model
    d_inner, h = ssm_dims(cfg)
    n, w = cfg.ssm_state, cfg.ssm_conv_width
    conv_ch = d_inner + 2 * n  # x, B, C all pass through the causal conv
    k1, k2, k3, k4 = jax.random.split(key, 4)
    # A in (-exp) parametrisation; dt bias init so softplus(dt_bias) ~ U[1e-3, 1e-1]
    dt = np.exp(
        np.random.RandomState(0).uniform(np.log(1e-3), np.log(1e-1), size=(h,))
    ).astype(np.float32)
    dt_bias = dt + np.log(-np.expm1(-dt))  # inverse softplus
    return {
        "in_proj": dense_init(k1, (d, d_inner * 2 + 2 * n + h), d, dtype),
        "conv_w": (jax.random.normal(k2, (w, conv_ch), jnp.float32) * (1.0 / np.sqrt(w))).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.log(jnp.arange(1, h + 1, dtype=jnp.float32)),
        "dt_bias": jnp.asarray(dt_bias),
        "D": jnp.ones((h,), jnp.float32),
        "norm_scale": jnp.zeros((d_inner,), dtype),
        "out_proj": dense_init(k3, (d_inner, d), d_inner, dtype, scale=1.0 / np.sqrt(2 * max(1, cfg.n_layers))),
    }


def _split_proj(p, cfg, x):
    """x: (B,S,D) -> z (B,S,d_inner), xBC (B,S,d_inner+2N), dt (B,S,H)."""
    d_inner, h = ssm_dims(cfg)
    n = cfg.ssm_state
    zxbcdt = x @ p["in_proj"]
    z = zxbcdt[..., :d_inner]
    xbc = zxbcdt[..., d_inner : 2 * d_inner + 2 * n]
    dt = zxbcdt[..., 2 * d_inner + 2 * n :]
    return z, xbc, dt


def _causal_conv(p, xbc, width):
    """Depthwise causal conv over the sequence axis.  xbc: (B,S,C)."""
    pad = jnp.pad(xbc, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + xbc.shape[1], :] * p["conv_w"][i][None, None, :]
        for i in range(width)
    )
    return jax.nn.silu(out + p["conv_b"][None, None, :])


def _segsum(da):
    """Log-decay matrix: L[t, s] = sum_{s < u <= t} da[u], -inf for s > t.

    da: (..., L) f32 -> (..., L, L).
    """
    L = da.shape[-1]
    cs = jnp.cumsum(da, axis=-1)
    # L[t,s] = cs[t] - cs[s]  (decay applied strictly after step s)
    mat = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool), k=0)
    return jnp.where(mask, mat, -jnp.inf)


def ssd_chunked(cfg, xh, Bm, Cm, dt_soft, A):
    """Chunked SSD scan.

    xh: (B,S,H,P)  Bm,Cm: (B,S,N)  dt_soft: (B,S,H) f32  A: (H,) f32 (<0)
    Returns y: (B,S,H,P) f32, final_state: (B,H,P,N) f32.
    """
    b, s, h, pdim = xh.shape
    n = Bm.shape[-1]
    L = min(cfg.ssm_chunk, s)
    while s % L:
        L //= 2
    nc = s // L

    # operands keep their storage dtype (bf16 at production configs) with
    # f32 ACCUMULATION via preferred_element_type - explicit .astype(f32)
    # here would materialise f32 copies of the (B,S,...) tensors in HBM
    # (EXPERIMENTS.md §Perf iteration 1); decay/cumsum math stays f32.
    dtype = xh.dtype
    xc = xh.reshape(b, nc, L, h, pdim)
    Bc = Bm.reshape(b, nc, L, n)
    Cc = Cm.reshape(b, nc, L, n)
    da = (dt_soft * A[None, None, :]).reshape(b, nc, L, h)  # (B,c,L,H) f32, <= 0

    # --- intra-chunk (attention-like, masked decay) ---
    Ldec = _segsum(jnp.moveaxis(da, -1, -2))  # (B,c,H,L,L)
    scores = jnp.einsum("bcln,bcmn->bclm", Cc, Bc,
                        preferred_element_type=jnp.float32)  # shared over H
    w = scores[:, :, None, :, :] * jnp.exp(Ldec)  # (B,c,H,L,L) f32
    xdt = xc * dt_soft.reshape(b, nc, L, h).astype(dtype)[..., None]  # (B,c,L,H,P)
    y_intra = jnp.einsum("bchlm,bcmhp->bclhp", w.astype(dtype), xdt,
                         preferred_element_type=jnp.float32)

    # --- chunk-final states ---
    cum = jnp.cumsum(da, axis=2)  # (B,c,L,H)
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)  # (B,c,L,H)
    states = jnp.einsum(
        "bclh,bcln,bclhp->bchpn",
        (decay_to_end * dt_soft.reshape(b, nc, L, h)).astype(dtype), Bc, xc,
        preferred_element_type=jnp.float32,
    )

    # --- inter-chunk recurrence over chunk index ---
    chunk_decay = jnp.exp(cum[:, :, -1, :])  # (B,c,H) total decay of a chunk

    def step(hprev, inp):
        st, dec = inp  # (B,H,P,N), (B,H)
        hnew = hprev * dec[:, :, None, None] + st
        return hnew, hprev  # emit state *entering* the chunk

    h0 = jnp.zeros((b, h, pdim, n), jnp.float32)
    hT, h_in = jax.lax.scan(
        step,
        h0,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
        unroll=min(cfg.ssm_scan_unroll, nc),
    )
    h_in = jnp.moveaxis(h_in, 0, 1)  # (B,c,H,P,N) state entering each chunk

    # --- contribution of the carried state ---
    in_decay = jnp.exp(cum)  # (B,c,L,H) decay from chunk start to step t
    y_inter = jnp.einsum("bcln,bchpn,bclh->bclhp", Cc,
                         h_in.astype(dtype), in_decay.astype(dtype),
                         preferred_element_type=jnp.float32)

    y = (y_intra + y_inter).reshape(b, s, h, pdim)
    return y, hT


def ssm_forward(p, cfg, x):
    """Training/prefill pass.  x: (B,S,D) normed -> (B,S,D)."""
    d_inner, h = ssm_dims(cfg)
    n, pdim = cfg.ssm_state, cfg.ssm_head_dim
    b, s, d = x.shape

    z, xbc, dt = _split_proj(p, cfg, x)
    xbc = _causal_conv(p, xbc, cfg.ssm_conv_width)
    xs = xbc[..., :d_inner].reshape(b, s, h, pdim)
    Bm = xbc[..., d_inner : d_inner + n]
    Cm = xbc[..., d_inner + n :]

    dt_soft = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None, :])
    A = -jnp.exp(p["A_log"])
    y, _ = ssd_chunked(cfg, xs, Bm, Cm, dt_soft, A)
    y = y + p["D"][None, None, :, None] * xs.astype(jnp.float32)

    y = y.reshape(b, s, d_inner).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rmsnorm_noscale(y, cfg.norm_eps) * (1.0 + p["norm_scale"].astype(jnp.float32)).astype(x.dtype)
    return y @ p["out_proj"]


# ---------------------------------------------------------------------------
# Decode path (recurrent, O(1) per token)
# ---------------------------------------------------------------------------


def ssm_init_cache(cfg, batch, dtype):
    d_inner, h = ssm_dims(cfg)
    n, w = cfg.ssm_state, cfg.ssm_conv_width
    return {
        "conv": jnp.zeros((batch, w - 1, d_inner + 2 * n), dtype),
        "state": jnp.zeros((batch, h, cfg.ssm_head_dim, n), jnp.float32),
    }


def ssm_decode(p, cfg, x, cache):
    """One-token recurrent step.  x: (B,1,D) -> (out (B,1,D), new cache)."""
    d_inner, h = ssm_dims(cfg)
    n, pdim, w = cfg.ssm_state, cfg.ssm_head_dim, cfg.ssm_conv_width
    b = x.shape[0]

    z, xbc, dt = _split_proj(p, cfg, x)  # (B,1,*)
    window = jnp.concatenate([cache["conv"], xbc], axis=1)  # (B,w,C)
    conv_out = jnp.sum(window * p["conv_w"][None, :, :], axis=1) + p["conv_b"]
    xbc1 = jax.nn.silu(conv_out)  # (B,C)
    new_conv = window[:, 1:, :]

    xs = xbc1[:, :d_inner].reshape(b, h, pdim).astype(jnp.float32)
    Bm = xbc1[:, d_inner : d_inner + n].astype(jnp.float32)
    Cm = xbc1[:, d_inner + n :].astype(jnp.float32)

    dt_soft = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"][None, :])  # (B,H)
    A = -jnp.exp(p["A_log"])  # (H,)
    decay = jnp.exp(dt_soft * A[None, :])  # (B,H)

    state = cache["state"] * decay[:, :, None, None] + jnp.einsum(
        "bh,bn,bhp->bhpn", dt_soft, Bm, xs
    )
    y = jnp.einsum("bn,bhpn->bhp", Cm, state) + p["D"][None, :, None] * xs

    y = y.reshape(b, 1, d_inner).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rmsnorm_noscale(y, cfg.norm_eps) * (1.0 + p["norm_scale"].astype(jnp.float32)).astype(x.dtype)
    return y @ p["out_proj"], {"conv": new_conv, "state": state}


def ssm_forward_with_cache(p, cfg, x):
    """Prefill pass that also returns the decode cache (conv tail + final
    recurrent state) so serving can continue token-by-token."""
    d_inner, h = ssm_dims(cfg)
    n, pdim = cfg.ssm_state, cfg.ssm_head_dim
    b, s, d = x.shape
    w = cfg.ssm_conv_width

    z, xbc_pre, dt = _split_proj(p, cfg, x)
    xbc = _causal_conv(p, xbc_pre, w)
    xs = xbc[..., :d_inner].reshape(b, s, h, pdim)
    Bm = xbc[..., d_inner : d_inner + n]
    Cm = xbc[..., d_inner + n :]

    dt_soft = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None, :])
    A = -jnp.exp(p["A_log"])
    y, hT = ssd_chunked(cfg, xs, Bm, Cm, dt_soft, A)
    y = y + p["D"][None, None, :, None] * xs.astype(jnp.float32)

    y = y.reshape(b, s, d_inner).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rmsnorm_noscale(y, cfg.norm_eps) * (1.0 + p["norm_scale"].astype(jnp.float32)).astype(x.dtype)
    # conv buffer = the last w-1 PRE-activation projections (matches decode)
    conv_tail = xbc_pre[:, -(w - 1):, :] if s >= w - 1 else jnp.pad(
        xbc_pre, ((0, 0), (w - 1 - s, 0), (0, 0)))
    cache = {"conv": conv_tail, "state": hT}
    return y @ p["out_proj"], cache
