"""pFedSOP: personalized federated learning with second-order optimization.

The paper's contribution, as pure-JAX pytree math (Sen & Mohan, 2025;
abstract and equation numbering in PAPER.md):

per client i at round t
  1. beta   = gompertz(angle(delta_i(t-1), delta(t-1)))          (Eq. 14)
  2. dp     = (1-beta) * delta_i + beta * delta                  (Eq. 15)
  3. step   = [dp dp^T + rho I]^{-1} dp   via Sherman-Morrison   (Eq. 18)
  4. x_it   = x_i(t-1) - eta1 * step                             (Eq. 19)
  5. T-step local SGD from x_it; delta_it = (x0 - xT)/eta2       (Eq. 11)
server
  6. delta_t = mean_i delta_it                                   (Eq. 13)

This module is pure math for ONE client; the federation-facing adapter
(``repro.core.baselines.PFedSOP``) wraps it in the ``FLMethod`` interface
documented on ``repro.core.baselines.FLMethod``, and the engine backends
in ``repro.fl.engine`` run it across clients (DESIGN.md §2/§3).

Everything operates on *pytrees* of parameters so the same code serves the
paper-faithful CNN reproduction, the 10 assigned transformer-family
architectures, and the sharded multi-pod deployment (the scalar reductions
become cross-`model`-shard psums under pjit; see launch/steps.py).

The rank-1 + identity structure of the regularized FIM collapses the
Sherman-Morrison step to a scalar rescale:

  F^{-1} dp = dp/rho - dp ||dp||^2 / (rho^2 + rho ||dp||^2)
            = dp / (rho + ||dp||^2)

We implement the explicit Sherman-Morrison expression (left) — faithful to
the paper's Algorithm 1 line 5 — and verify the algebraic collapse (right)
and the dense matrix-inverse oracle agreement in tests/test_pfedsop_math.py.

The round-start update (steps 1-4 above) has two interchangeable
implementations selected by ``PFedSOPConfig.update_impl`` (DESIGN.md §9):
the per-leaf pytree math in this module (the reference), and the fused
Pallas kernel (``repro.kernels.pfedsop_update``) reached through a
flatten-once adapter whose ``jax.custom_batching.custom_vmap`` rule turns
the engines' per-client vmap into ONE batched (clients, N) kernel launch
per round.  Both impls agree within fp32 reduction-order tolerance
(tests/test_kernel_dispatch.py).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels.dispatch import resolve_update_impl
from repro.utils.pytree import (
    tree_dot,
    tree_flatten_to_vector,
    tree_lerp,
    tree_scale,
    tree_sqnorm,
    tree_sub,
    tree_unflatten_from_vector,
    tree_where,
    tree_zeros_like,
)

Pytree = Any


@dataclass(frozen=True)
class PFedSOPConfig:
    """Hyperparameters (paper Sec. V-B4: rho=1, lambda=1, batch 50, 1 epoch)."""

    eta1: float = 0.01  # personalization learning rate (Eq. 19)
    eta2: float = 0.01  # local-SGD learning rate (Eq. 10)
    rho: float = 1.0  # FIM regularization (Eq. 17)
    lam: float = 1.0  # Gompertz steepness (Eq. 14)
    local_iters: int = 0  # T; 0 = derive from data (one epoch)
    use_pc: bool = True  # personalization component (ablation Table III)
    eps: float = 1e-12  # cosine-similarity guard
    # async aggregation only (DESIGN.md §10): exponent of the polynomial
    # staleness discount composed with the Gompertz weight in stale_blend;
    # irrelevant to the synchronous driver (staleness is identically zero).
    staleness_exp: float = 0.5
    # round-start update implementation (repro.kernels.dispatch, DESIGN.md
    # §9): "auto" = fused Pallas kernel on TPU, pytree reference elsewhere;
    # "reference" / "kernel" / "kernel_interpret" force one path.
    update_impl: str = "auto"


class ClientState(NamedTuple):
    """Per-client persistent state.

    A pytree, so a K-client federation is one ClientState with a leading
    client axis on every leaf (vmap-able simulation backend) or one
    ClientState per pod (distributed backend).
    """

    params: Pytree  # personalized model x_i
    delta: Pytree  # latest local gradient update Delta_i
    has_delta: jnp.ndarray  # bool scalar: False for new clients
    rounds_seen: jnp.ndarray  # int32 scalar (diagnostics)


def init_client_state(params: Pytree) -> ClientState:
    return ClientState(
        params=params,
        delta=tree_zeros_like(params),
        has_delta=jnp.asarray(False),
        rounds_seen=jnp.asarray(0, jnp.int32),
    )


# ---------------------------------------------------------------------------
# Personalized aggregation (Algorithm 1 lines 1-4)
# ---------------------------------------------------------------------------


def gompertz_weight(local_delta: Pytree, global_delta: Pytree, lam, eps=1e-12):
    """Aggregation weight beta from the Gompertz-normalized angle.

    Returns (beta, aux) where aux carries the intermediate scalars for
    diagnostics.  All reductions are f32.  Zero-norm guard: if either update
    is (numerically) zero the angle is undefined; we fall back to theta=pi/2
    ("no information"), matching the paper's neutral-trust reading.
    """
    dot = tree_dot(local_delta, global_delta)
    nl2 = tree_sqnorm(local_delta)
    ng2 = tree_sqnorm(global_delta)
    denom = jnp.sqrt(nl2) * jnp.sqrt(ng2)
    ok = denom > eps
    sim = jnp.where(ok, dot / jnp.where(ok, denom, 1.0), 0.0)
    sim = jnp.clip(sim, -1.0, 1.0)
    theta = jnp.arccos(sim)  # [0, pi]
    beta = 1.0 - jnp.exp(-jnp.exp(-lam * (theta - 1.0)))  # Eq. 14
    return beta, {"sim": sim, "theta": theta, "beta": beta, "dot": dot,
                  "local_sqnorm": nl2, "global_sqnorm": ng2}


def personalized_delta(local_delta, global_delta, lam, eps=1e-12):
    """Eq. 15: dp = (1-beta) * delta_i + beta * delta."""
    beta, aux = gompertz_weight(local_delta, global_delta, lam, eps)
    return tree_lerp(beta, local_delta, global_delta), aux


def theta_from_beta(beta, lam):
    """Invert Eq. 14 to recover the angle theta from a recorded beta.

    Host-side numpy, for diagnostics only (the observability layer's
    per-round theta histograms, DESIGN.md §13): the client programs
    materialize beta in their metrics, and

        theta = 1 - ln(-ln(1 - beta)) / lam

    maps it back.  beta is clipped away from {0, 1} (where the double
    exponential saturates) and the result to Eq. 14's domain [0, pi].
    """
    import numpy as np

    b = np.clip(np.asarray(beta, np.float64), 1e-9, 1.0 - 1e-9)
    theta = 1.0 - np.log(-np.log1p(-b)) / float(lam)
    return np.clip(theta, 0.0, np.pi)


# ---------------------------------------------------------------------------
# Sherman-Morrison second-order step (Algorithm 1 line 5, Eq. 18)
# ---------------------------------------------------------------------------


def sherman_morrison_step(delta_p: Pytree, rho):
    """F^{-1} dp for F = dp dp^T + rho I, via Sherman-Morrison (Eq. 18).

    step = dp/rho - dp * ||dp||^2 / (rho^2 + rho ||dp||^2)

    Equivalent to dp / (rho + ||dp||^2); the explicit two-term form is kept
    to mirror the paper (tests assert the identity).
    """
    sq = tree_sqnorm(delta_p)  # dp^T dp, f32
    coeff = 1.0 / rho - sq / (rho**2 + rho * sq)
    return tree_scale(coeff, delta_p)


@functools.lru_cache(maxsize=None)
def _fused_flat_update(eta1, rho, lam, eps, interpret, shard=None):
    """Flat-vector fused update with a custom vmap rule (cached per-config).

    The primal runs the single-client kernel; the vmap rule — fired by the
    engines' per-client ``jax.vmap`` (also inside the mesh engines'
    shard_map body, where it sees each shard's local client slice) —
    dispatches the whole batch to the (clients, N) grid kernel in one
    launch.  An unbatched global delta (the usual replicated server
    broadcast) is passed through as (N,) so the kernel reads one shared
    buffer instead of materializing C copies.

    ``shard`` is the ``(model_axis_name, n_shards)`` announced by a mesh
    engine whose mesh carries a model-role axis
    (``repro.kernels.dispatch.model_shard_axis``, DESIGN.md §11): both the
    primal and the batched rule then take the model-sharded kernel layout,
    which splits the flattened-N tile rows over the mesh axis and combines
    the three Gompertz scalars with a cross-shard psum — bit-identical to
    the unsharded kernel.
    """
    from repro.kernels.pfedsop_update.ops import (
        pfedsop_update,
        pfedsop_update_batched,
        pfedsop_update_batched_sharded,
    )

    if shard:
        axis_name, n_shards = shard

        def _batched(x, di, dg):
            return pfedsop_update_batched_sharded(
                x, di, dg, axis_name, n_shards, eta1=eta1, rho=rho, lam=lam,
                eps=eps, interpret=interpret)
    else:

        def _batched(x, di, dg):
            return pfedsop_update_batched(x, di, dg, eta1=eta1, rho=rho,
                                          lam=lam, eps=eps,
                                          interpret=interpret)

    @jax.custom_batching.custom_vmap
    def fused(x, di, dg):
        if shard:  # unvmapped single client: the batched layout with C=1
            out, beta = _batched(x[None], di[None], dg)
            return out[0], beta[0]
        return pfedsop_update(x, di, dg, eta1=eta1, rho=rho, lam=lam,
                              eps=eps, interpret=interpret)

    @fused.def_vmap
    def _batched_rule(axis_size, in_batched, x, di, dg):
        x_b, di_b, _ = in_batched
        if not x_b:
            x = jnp.broadcast_to(x, (axis_size,) + x.shape)
        if not di_b:
            di = jnp.broadcast_to(di, (axis_size,) + di.shape)
        out, beta = _batched(x, di, dg)
        return (out, beta), (True, True)

    return fused


def _personalize_fused(params, local_delta, global_delta, cfg, interpret):
    """Kernel-impl personalize: flatten once, one fused call, unflatten once.

    The f32 flat vectors concatenate all leaves, so the three reductions
    run over the whole model in one tiled pass (vs. per-leaf partial sums
    in the reference) — numerically equal up to fp32 reduction order.
    ``aux`` carries only beta; the reference path's extra diagnostics
    (sim/theta/...) would need a third sweep the fusion exists to avoid.
    The model-shard context (set by a §11 mesh engine around body tracing)
    is read host-side here, so the sharded layout is baked into the trace.
    """
    from repro.kernels.dispatch import current_model_shard, kernel_scope

    xv = tree_flatten_to_vector(params)
    div = tree_flatten_to_vector(local_delta)
    dgv = tree_flatten_to_vector(global_delta)
    fused = _fused_flat_update(cfg.eta1, cfg.rho, cfg.lam, cfg.eps, interpret,
                               shard=current_model_shard())
    with kernel_scope("pfedsop_update",
                      "kernel_interpret" if interpret else "kernel"):
        new_v, beta = fused(xv, div, dgv)
    return tree_unflatten_from_vector(new_v, params), {"beta": beta}


def personalize(
    params: Pytree,
    local_delta: Pytree,
    global_delta: Pytree,
    cfg: PFedSOPConfig,
):
    """Algorithm 1: returns (x_it, aux) from (x_i(t-1), Delta_i, Delta).

    Dispatches on ``cfg.update_impl`` (resolved host-side, so the choice is
    baked into the trace): the fused Pallas kernel covers the personalized
    blend + Sherman-Morrison step; the no-PC ablation removes the blend the
    kernel fuses, so it always runs the reference pytree path.
    """
    impl = resolve_update_impl(cfg.update_impl)
    if cfg.use_pc and impl != "reference":
        return _personalize_fused(params, local_delta, global_delta, cfg,
                                  interpret=impl == "kernel_interpret")
    if cfg.use_pc:
        dp, aux = personalized_delta(local_delta, global_delta, cfg.lam, cfg.eps)
    else:
        # ablation: no personalization component -> use the global update
        dp, aux = global_delta, {"beta": jnp.float32(1.0)}
    step = sherman_morrison_step(dp, cfg.rho)
    new_params = jax.tree.map(
        lambda x, s: (x.astype(jnp.float32) - cfg.eta1 * s.astype(jnp.float32)).astype(x.dtype),
        params,
        step,
    )
    return new_params, aux


# ---------------------------------------------------------------------------
# Local training (Algorithm 2)
# ---------------------------------------------------------------------------


def local_sgd_delta(
    loss_fn: Callable[[Pytree, Any], jnp.ndarray],
    params: Pytree,
    batches: Any,  # pytree with leading axis T (local iterations)
    eta2: float,
):
    """T iterations of SGD; returns (delta_i, final_params, mean_loss).

    delta_i = (x0 - xT)/eta2 = sum of the per-iteration stochastic gradients
    (Eq. 11/12 — verified by test against an explicit gradient sum).

    The per-step gradient dispatches through ``chunked_value_and_grad``
    (DESIGN.md §11): plain ``jax.value_and_grad`` at the default
    ``grad_chunks = 1``, the canonical chunk-tree reduction otherwise —
    including the data-axis-sharded layout inside a mesh engine body.
    """
    from repro.optim.sgd import chunked_value_and_grad

    grad_fn = chunked_value_and_grad(loss_fn)

    def step(p, batch):
        loss, g = grad_fn(p, batch)
        p = jax.tree.map(
            lambda x, gi: (x.astype(jnp.float32) - eta2 * gi.astype(jnp.float32)).astype(x.dtype),
            p,
            g,
        )
        return p, loss

    final, losses = jax.lax.scan(step, params, batches)
    delta = tree_scale(1.0 / eta2, tree_sub(params, final))
    return delta, final, jnp.mean(losses)


# ---------------------------------------------------------------------------
# Full client round (Algorithm 3 lines 4-11) and server aggregation
# ---------------------------------------------------------------------------


def client_round(
    loss_fn: Callable[[Pytree, Any], jnp.ndarray],
    state: ClientState,
    global_delta: Pytree,
    global_has_delta: jnp.ndarray,
    batches: Any,
    cfg: PFedSOPConfig,
    init_params: Pytree | None = None,
):
    """One pFedSOP round for one client.  Fully traceable (vmap/shard_map).

    New clients (has_delta=False) skip personalization and start local
    training from their stored params (which the runtime seeds with the
    shared random init, Algorithm 3 line 6).  Round 1 has no global update
    yet (global_has_delta=False) -> also skip personalization.
    """
    del init_params  # runtime seeds state.params; kept for API clarity
    can_personalize = jnp.logical_and(state.has_delta, global_has_delta)
    personalized, aux = personalize(state.params, state.delta, global_delta, cfg)
    params = tree_where(can_personalize, personalized, state.params)

    delta, final_params, loss = local_sgd_delta(loss_fn, params, batches, cfg.eta2)

    new_state = ClientState(
        params=final_params,
        delta=delta,
        has_delta=jnp.asarray(True),
        rounds_seen=state.rounds_seen + 1,
    )
    metrics = {"loss": loss, "beta": aux.get("beta", jnp.float32(1.0)),
               "personalized": can_personalize}
    return new_state, delta, metrics


def server_aggregate(deltas: Pytree) -> Pytree:
    """Eq. 13: mean over the client axis (leading axis of every leaf).

    Routed through the canonically associated ``cohort_mean`` (DESIGN.md
    §11) so the replicated aggregation program, the sharded-at-rest
    program (where this traces inside a ``client_shard_axis`` context and
    the leading axis is the shard-local cohort slice) and the async
    driver's host-stacked flush all produce bit-identical means.
    """
    from repro.optim.reduce import cohort_mean

    return cohort_mean(deltas)


# ---------------------------------------------------------------------------
# Staleness-weighted aggregation (async federation, DESIGN.md §10)
# ---------------------------------------------------------------------------


def staleness_discount(staleness, exponent):
    """FedBuff-style polynomial discount s(tau) = (1 + tau)^(-exponent), f32.

    ``staleness`` counts server versions elapsed since the upload's client
    was dispatched.  tau = 0 yields exactly 1.0 (1^x == 1 in IEEE), which is
    what lets a buffer of fresh uploads aggregate bit-identically to the
    synchronous path -- the degenerate-sync anchor of the async subsystem.
    """
    tau = jnp.asarray(staleness, jnp.float32)
    return (1.0 + tau) ** jnp.float32(-exponent)


def stale_blend(upload, global_delta, discount, lam, eps=1e-12):
    """Down-blend ONE stale local delta toward the current global delta.

    Composes the staleness discount s(tau) with the Gompertz-normalized
    angle weight (Eq. 14):

        c       = (1 - s) * (1 - beta)
        blended = (1 - c) * upload + c * global_delta

    beta is Eq. 14's trust-toward-global weight -- large when the upload
    agrees with the current global direction -- so (1 - beta) measures
    disagreement.  A stale AND conflicting delta is pulled hardest toward
    the global consensus; a fresh upload (s = 1 -> c = 0) passes through
    bit-exactly.  Feeding the blended deltas to the Eq. 13 mean
    down-*blends* staleness into the aggregate instead of merely
    down-averaging it (the generic FedAvg-family default in
    ``repro.core.baselines``).
    """
    beta, _ = gompertz_weight(upload, global_delta, lam, eps)
    c = (1.0 - discount) * (1.0 - beta)
    return tree_lerp(c, upload, global_delta)
