"""The paper's contribution (pFedSOP) + the baseline FL method zoo."""
from repro.core import pfedsop  # noqa: F401
from repro.core import baselines  # noqa: F401
