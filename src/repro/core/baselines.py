"""Baseline FL methods from the paper's comparison set (Table II).

Every method implements the ``FLMethod`` interface below — THE definitive
statement of the method contract consumed by the federation engine
(``repro.fl.engine``; architecture in DESIGN.md §2/§3).

Methods:  FedAvg, FedProx (mu), FedAvg-FT, FedProx-FT, Ditto (lam),
FedRep (head/body split), LocalOnly, SCAFFOLD, FedExP, and the pFedSOP
adapter around ``repro.core.pfedsop``.  All local training is plain SGD
(Algorithm 2 of the paper; same for the baselines, matching the paper's
setup in PAPER.md Sec. V).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Optional, Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from repro.core import pfedsop as pf
from repro.optim.reduce import cohort_mean, cohort_size, cohort_sum
from repro.optim.sgd import chunked_value_and_grad
from repro.utils.pytree import tree_scale, tree_sub, tree_zeros_like

Pytree = Any


def _mean_like(uploads):
    """Eq.-13-style mean over the stacked client axis, cast back to the
    leaf dtype.  ``cohort_mean`` (repro.optim.reduce) is canonically
    associated and client-shard-aware, so the FedAvg-family aggregation
    is bitwise identical between the replicated program, the §11 sharded
    aggregation program, and the async host-stacked flush."""
    return jax.tree.map(
        lambda u, m: m.astype(u.dtype), uploads, cohort_mean(uploads)
    )


@runtime_checkable
class FLMethod(Protocol):
    """The traceable FL-method contract (documented once, here).

    A method is a frozen, hashable object (so it can be closed over by a
    jitted round function) exposing five functions.  Everything except the
    two ``init_*`` hooks is traced — it must be vmap/shard_map-safe: no
    python control flow on traced values, no shape-dependent branching
    (use ``jax.lax`` / masking instead, cf. ``tree_where`` in pfedsop).

    init_client(params) -> client_state
        Per-client persistent state from the shared random init.  The
        runtime stacks it on a leading K axis (one pytree for the whole
        federation, DESIGN.md §3).
    init_server(params) -> broadcast
        What the server sends every round (replicated across shards).
    client_round(loss_fn, state, broadcast, batches) ->
            (new_state, upload, metrics)
        One client's local phase for one round: ``batches`` has a leading
        local-iteration axis T (scanned).  ``metrics`` must contain at
        least {"loss": scalar}.  For pFedSOP this is Algorithm 3 lines
        4-11 / Eqs. 10-19 of PAPER.md.
    server_update(broadcast, uploads) -> new_broadcast
        Aggregation over the stacked upload axis (leading axis of every
        leaf).  Under the shard_map backend that axis is device-sharded,
        so reductions over it compile to cross-shard psums (Eq. 13 of
        PAPER.md for pFedSOP's mean).
    server_update_stale(broadcast, uploads, staleness) -> new_broadcast
        Buffered/asynchronous aggregation (DESIGN.md §10): like
        ``server_update``, but upload i additionally carries its staleness
        tau_i (int32, shape (B,)) -- the number of server versions applied
        since that client was dispatched.  MUST reduce to ``server_update``
        bit-exactly when every tau is 0 (a buffer of fresh uploads); that
        identity is what makes the degenerate async configuration reproduce
        the synchronous history bitwise (tests/test_async_federation.py).
        The FedAvg-family default wraps ``server_update`` in a mean-one
        normalized polynomial staleness discount (``staleness_weights``);
        pFedSOP instead composes the discount with the Gompertz angle
        weight (``repro.core.pfedsop.stale_blend``) so stale deltas are
        down-blended toward the global update, not just down-averaged.
        Only the asynchronous driver calls this hook, so a sync-only
        custom method may omit it (``validate_method`` requires it only
        for ``AsyncFederation``).
    eval_params(state, broadcast) -> params
        The parameters a client deploys for local test accuracy
        (personalized methods return per-client params; FedAvg-family
        return the broadcast model).

    ``repro.fl.runtime.validate_method`` checks structural conformance at
    federation construction time.
    """

    name: str

    def init_client(self, params: Pytree) -> Pytree: ...

    def init_server(self, params: Pytree) -> Pytree: ...

    def client_round(self, loss_fn, state, broadcast, batches): ...

    def server_update(self, broadcast, uploads): ...

    def server_update_stale(self, broadcast, uploads, staleness): ...

    def eval_params(self, state, broadcast) -> Pytree: ...


def staleness_weights(staleness, exponent):
    """Mean-one normalized polynomial staleness weights, f32 (B,).

    w_i = s_i / mean(s) with s_i = (1 + tau_i)^(-exponent)
    (``repro.core.pfedsop.staleness_discount``).  Normalizing to mean one
    keeps a weighted mean an affine combination -- FedAvg-family uploads
    are full parameter vectors, so an unnormalized discount would shrink
    the averaged model toward zero.  An all-fresh buffer (tau = 0 ->
    s = 1.0 exactly) yields exactly 1.0 per upload, preserving the
    sync-degenerate bitwise identity of ``server_update_stale``.
    """
    s = pf.staleness_discount(staleness, exponent)
    return s / jnp.mean(s)


# ---------------------------------------------------------------------------
# Shared local-SGD machinery
# ---------------------------------------------------------------------------


def local_train(
    loss_fn: Callable[[Pytree, Any], jnp.ndarray],
    params: Pytree,
    batches: Any,  # leading axis T
    lr: float,
    mask: Optional[Pytree] = None,
    prox: Optional[tuple] = None,  # (mu, ref_params)
):
    """T SGD iterations; returns (final_params, mean_loss).

    mask: 0/1 pytree freezing parameters (FedRep); prox: FedProx/Ditto
    proximal term mu/2 ||x - ref||^2 added to the objective.
    """

    def full_loss(p, batch):
        loss = loss_fn(p, batch)
        if prox is not None:
            mu, ref = prox
            sq = pf.tree_sqnorm(tree_sub(p, ref))
            loss = loss + 0.5 * mu * sq
        return loss

    grad_fn = chunked_value_and_grad(full_loss)

    def step(p, batch):
        loss, g = grad_fn(p, batch)
        if mask is not None:
            g = jax.tree.map(lambda gi, m: gi * m, g, mask)
        p = jax.tree.map(
            lambda x, gi: (x.astype(jnp.float32) - lr * gi.astype(jnp.float32)).astype(x.dtype),
            p,
            g,
        )
        return p, loss

    final, losses = jax.lax.scan(step, params, batches)
    return final, jnp.mean(losses)


# ---------------------------------------------------------------------------
# Method classes
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class FedAvg:
    lr: float = 0.01
    name: str = "fedavg"
    # polynomial staleness-discount exponent for the async aggregation hook
    # (server_update_stale, DESIGN.md §10); unused by the synchronous driver.
    staleness_exp: float = 0.5

    def init_client(self, params):
        return {}

    def init_server(self, params):
        return params

    def client_round(self, loss_fn, state, broadcast, batches):
        trained, loss = local_train(loss_fn, broadcast, batches, self.lr)
        return state, trained, {"loss": loss}

    def server_update(self, broadcast, uploads):
        return _mean_like(uploads)

    def server_update_stale(self, broadcast, uploads, staleness):
        """Default staleness hook: normalized polynomial discount wrapping
        ``server_update`` (shared by the whole FedAvg family -- the
        subclasses only change ``client_round``/``server_update``, which
        this wrapper composes with).  See FLMethod for the contract."""
        w = staleness_weights(staleness, self.staleness_exp)
        scaled = jax.tree.map(
            lambda u: (u.astype(jnp.float32)
                       * w.reshape((-1,) + (1,) * (u.ndim - 1))).astype(u.dtype),
            uploads,
        )
        return self.server_update(broadcast, scaled)

    def eval_params(self, state, broadcast):
        return broadcast


@dataclass(frozen=True)
class FedProx(FedAvg):
    mu: float = 0.1
    name: str = "fedprox"

    def client_round(self, loss_fn, state, broadcast, batches):
        trained, loss = local_train(
            loss_fn, broadcast, batches, self.lr, prox=(self.mu, broadcast)
        )
        return state, trained, {"loss": loss}


@dataclass(frozen=True)
class FedAvgFT(FedAvg):
    """FedAvg + per-round fine-tune: the personalized model is the global
    model fine-tuned on local data BEFORE local training (paper Sec. V-B2);
    the upload continues training from the fine-tuned point (O(2 N_i d))."""

    name: str = "fedavg_ft"

    def init_client(self, params):
        return {"personal": params}

    def client_round(self, loss_fn, state, broadcast, batches):
        finetuned, loss_ft = local_train(loss_fn, broadcast, batches, self.lr)
        trained, loss = local_train(loss_fn, finetuned, batches, self.lr)
        return {"personal": finetuned}, trained, {"loss": 0.5 * (loss + loss_ft)}

    def eval_params(self, state, broadcast):
        return state["personal"]


@dataclass(frozen=True)
class FedProxFT(FedAvgFT):
    mu: float = 0.1
    name: str = "fedprox_ft"

    def client_round(self, loss_fn, state, broadcast, batches):
        finetuned, loss_ft = local_train(loss_fn, broadcast, batches, self.lr)
        trained, loss = local_train(
            loss_fn, finetuned, batches, self.lr, prox=(self.mu, broadcast)
        )
        return {"personal": finetuned}, trained, {"loss": 0.5 * (loss + loss_ft)}


@dataclass(frozen=True)
class Ditto(FedAvg):
    """Ditto: global track = FedAvg; personal track trained with a proximal
    pull toward the received global model (lam)."""

    lam: float = 0.1
    name: str = "ditto"

    def init_client(self, params):
        return {"personal": params}

    def client_round(self, loss_fn, state, broadcast, batches):
        trained, loss_g = local_train(loss_fn, broadcast, batches, self.lr)
        personal, loss_p = local_train(
            loss_fn, state["personal"], batches, self.lr, prox=(self.lam, broadcast)
        )
        return {"personal": personal}, trained, {"loss": loss_p}

    def eval_params(self, state, broadcast):
        return state["personal"]


@dataclass(frozen=True)
class FedRep(FedAvg):
    """FedRep: aggregate the body (feature extractor); the head stays local.
    head_predicate(path) -> True marks head leaves (e.g. the final fc)."""

    head_predicate: Callable = None  # set at construction
    name: str = "fedrep"

    def _masks(self, params):
        def is_head(path):
            return self.head_predicate("/".join(str(k) for k in path))

        head = jax.tree_util.tree_map_with_path(
            lambda path, p: jnp.asarray(1.0 if is_head(path) else 0.0, jnp.float32), params
        )
        body = jax.tree.map(lambda m: 1.0 - m, head)
        return head, body

    def init_client(self, params):
        return {"head": params}  # full tree; only head leaves are used

    def client_round(self, loss_fn, state, broadcast, batches):
        head_mask, body_mask = self._masks(broadcast)
        # local model = broadcast body + stored head
        params = jax.tree.map(
            lambda b, h, m: jnp.where(m > 0, h, b), broadcast, state["head"], head_mask
        )
        params, _ = local_train(loss_fn, params, batches, self.lr, mask=head_mask)
        params, loss = local_train(loss_fn, params, batches, self.lr, mask=body_mask)
        return {"head": params}, params, {"loss": loss}

    def server_update(self, broadcast, uploads):
        # aggregate everything; the head rows are overwritten locally anyway
        return _mean_like(uploads)

    def eval_params(self, state, broadcast):
        head_mask, _ = self._masks(broadcast)
        return jax.tree.map(
            lambda b, h, m: jnp.where(m > 0, h, b), broadcast, state["head"], head_mask
        )


@dataclass(frozen=True)
class LocalOnly(FedAvg):
    """No communication - each client trains alone (overfitting reference)."""

    name: str = "local"

    def init_client(self, params):
        return {"personal": params}

    def client_round(self, loss_fn, state, broadcast, batches):
        personal, loss = local_train(loss_fn, state["personal"], batches, self.lr)
        return {"personal": personal}, tree_zeros_like(broadcast), {"loss": loss}

    def server_update(self, broadcast, uploads):
        return broadcast  # nothing aggregated

    def eval_params(self, state, broadcast):
        return state["personal"]


@dataclass(frozen=True)
class PFedSOP:
    """Adapter around repro.core.pfedsop for the runtime interface.

    broadcast = (global_delta, has_global); upload = local delta;
    client_state = pfedsop.ClientState.

    The round-start update impl (pytree reference vs. fused Pallas kernel,
    DESIGN.md §9) is carried on ``cfg.update_impl``; a run-level override
    (``FLRunConfig.update_impl``) is pushed in here by
    ``repro.fl.runtime.override_update_impl`` via ``dataclasses.replace``
    — the method stays frozen/hashable, so the jitted round function can
    still close over it.
    """

    cfg: pf.PFedSOPConfig = field(default_factory=pf.PFedSOPConfig)
    name: str = "pfedsop"

    def init_client(self, params):
        return pf.init_client_state(params)

    def init_server(self, params):
        return {
            "delta": tree_zeros_like(params),
            "has_delta": jnp.asarray(False),
        }

    def client_round(self, loss_fn, state, broadcast, batches):
        new_state, delta, metrics = pf.client_round(
            loss_fn, state, broadcast["delta"], broadcast["has_delta"], batches, self.cfg
        )
        return new_state, delta, metrics

    def server_update(self, broadcast, uploads):
        return {
            "delta": pf.server_aggregate(uploads),
            "has_delta": jnp.asarray(True),
        }

    def server_update_stale(self, broadcast, uploads, staleness):
        """Staleness-composed aggregation (DESIGN.md §10): each upload is
        down-blended toward the current global delta with weight
        (1 - s(tau)) * (1 - beta) -- the polynomial discount composed with
        the Gompertz angle weight (``repro.core.pfedsop.stale_blend``) --
        before the usual Eq. 13 mean.  Fresh uploads (tau = 0) pass through
        bit-exactly.  This runs on the server (cold) path only, so the
        fused round-start update keeps dispatching through the §9 kernel
        layer unchanged."""
        s = pf.staleness_discount(staleness, self.cfg.staleness_exp)
        blended = jax.vmap(
            lambda u, si: pf.stale_blend(u, broadcast["delta"], si,
                                         self.cfg.lam, self.cfg.eps)
        )(uploads, s)
        return {
            "delta": pf.server_aggregate(blended),
            "has_delta": jnp.asarray(True),
        }

    def eval_params(self, state, broadcast):
        return state.params


METHODS = {
    "fedavg": FedAvg,
    "fedprox": FedProx,
    "fedavg_ft": FedAvgFT,
    "fedprox_ft": FedProxFT,
    "ditto": Ditto,
    "fedrep": FedRep,
    "local": LocalOnly,
    "pfedsop": PFedSOP,
}


@dataclass(frozen=True)
class Scaffold(FedAvg):
    """SCAFFOLD (Karimireddy et al., 2020): control variates correct the
    client drift.  Client keeps c_i; server broadcast carries (x, c).
    Option II update of c_i (difference form), full-batch variant.

    client:  y <- y - lr * (g(y) - c_i + c)         (T iterations)
             c_i' = c_i - c + (x - y_T)/(T * lr)
             upload (y_T, c_i' - c_i)
    server:  x <- mean(y_T);  c <- c + mean(dc) * |S|/K  (we use |S|=K'
             participating fraction folded into the mean, standard sim.)
    """

    name: str = "scaffold"

    def init_client(self, params):
        return {"c_i": tree_zeros_like(params)}

    def init_server(self, params):
        return {"x": params, "c": tree_zeros_like(params)}

    def client_round(self, loss_fn, state, broadcast, batches):
        x, c = broadcast["x"], broadcast["c"]
        c_i = state["c_i"]
        correction = jax.tree.map(
            lambda ci, cg: (cg.astype(jnp.float32) - ci.astype(jnp.float32)),
            c_i, c,
        )
        grad_fn = chunked_value_and_grad(loss_fn)

        def step(p, batch):
            loss, g = grad_fn(p, batch)
            p = jax.tree.map(
                lambda w, gi, corr: (
                    w.astype(jnp.float32) - self.lr * (gi.astype(jnp.float32) + corr)
                ).astype(w.dtype),
                p, g, correction,
            )
            return p, loss

        final, losses = jax.lax.scan(step, x, batches)
        t = batches_len(batches)
        new_c_i = jax.tree.map(
            lambda ci, cg, x0, xt: (
                ci.astype(jnp.float32) - cg.astype(jnp.float32)
                + (x0.astype(jnp.float32) - xt.astype(jnp.float32)) / (t * self.lr)
            ).astype(ci.dtype),
            c_i, c, x, final,
        )
        dc = jax.tree.map(lambda a, b: a.astype(jnp.float32) - b.astype(jnp.float32),
                          new_c_i, c_i)
        return {"c_i": new_c_i}, {"y": final, "dc": dc}, {"loss": jnp.mean(losses)}

    def server_update(self, broadcast, uploads):
        new_x = jax.tree.map(
            lambda old, m: m.astype(old.dtype),
            broadcast["x"], cohort_mean(uploads["y"]))
        new_c = jax.tree.map(
            lambda cg, m: (cg.astype(jnp.float32) + m).astype(cg.dtype),
            broadcast["c"], cohort_mean(uploads["dc"]))
        return {"x": new_x, "c": new_c}

    def eval_params(self, state, broadcast):
        return broadcast["x"]


@dataclass(frozen=True)
class FedExP(FedAvg):
    """FedExP (Jhunjhunwala et al., ICLR 2023): server-side adaptive
    extrapolation.  eta_server = max(1, ||mean delta||^2-based POCS step)

        eta_g = max(1, sum_i ||d_i||^2 / (2 K' ||mean d||^2 + eps))
        x <- x - eta_g * mean(d_i),  d_i = x - y_i
    """

    eps: float = 1e-3
    name: str = "fedexp"

    def client_round(self, loss_fn, state, broadcast, batches):
        trained, loss = local_train(loss_fn, broadcast, batches, self.lr)
        delta = jax.tree.map(
            lambda a, b: a.astype(jnp.float32) - b.astype(jnp.float32),
            broadcast, trained,
        )
        return state, delta, {"loss": loss}

    def server_update(self, broadcast, uploads):
        # every cohort reduction is canonically associated AND client-
        # shard-aware: the mean, the per-client sqnorm sum (locally
        # vmapped over this shard's rows, combined in shard order) and
        # K' itself all see the full cohort under a §11 sharded program
        mean_d = cohort_mean(uploads)
        from repro.utils.pytree import tree_sqnorm

        n_local = jax.tree.leaves(uploads)[0].shape[0]
        per_client_sq = jax.vmap(lambda i: tree_sqnorm(
            jax.tree.map(lambda v: v[i], uploads)))(jnp.arange(n_local))
        kprime = cohort_size(n_local)
        mean_sq = tree_sqnorm(mean_d)
        eta_g = jnp.maximum(1.0, cohort_sum(per_client_sq) /
                            (2.0 * kprime * (mean_sq + self.eps)))
        return jax.tree.map(
            lambda x, d: (x.astype(jnp.float32) - eta_g * d).astype(x.dtype),
            broadcast, mean_d,
        )


def batches_len(batches):
    """Static length T of the leading scan axis of a batch pytree."""
    return jax.tree.leaves(batches)[0].shape[0]


METHODS["scaffold"] = Scaffold
METHODS["fedexp"] = FedExP
