"""Canonical ordered reductions for bitwise-reproducible aggregation.

Floating-point addition is not associative, so "the mean over the client
axis" (Eq. 13 of PAPER.md) only names a *value class*: ``jnp.mean`` lets
XLA pick the association, and the pick differs between a host-side mean
over a replicated cohort and a cross-device reduction over a sharded one.
The sharded-at-rest round loop (DESIGN.md §11) requires the two to agree
**bitwise**, so every cohort reduction in the codebase routes through one
explicitly associated reduction instead:

  ``ordered_axis_sum``  top-down binary halving over the leading axis —
                        split n rows into [0, n//2) and [n//2, n), reduce
                        each recursively, add the two partials.

The payoff is a provable decomposition: for a client axis of D shards
(D a power of two dividing the cohort K'), the first log2(D) levels of
the halving tree split exactly at shard boundaries, so

  tree(K' rows)  ==  tree_over_D_partials( tree(local K'/D rows) )

with *identical* operands and association on both sides.  The sharded
aggregation program (``MeshBackend.aggregate_phase``) therefore computes
each shard's local partial, all-gathers the D partials in shard order,
and applies the same halving tree over them — bit-identical to the
replicated program, by construction rather than by luck.  The same
scheme fixes the data-axis gradient reduction (``optim.sgd.
chunked_value_and_grad``): the chunk tree is the unit of semantics, and
"which device computed which chunk" stops mattering.

Context plumbing: ``repro.kernels.dispatch.client_shard_axis`` /
``data_shard_axis`` announce the active mesh axes around shard_map body
tracing (the same host-side mechanism as ``model_shard_axis``), and the
helpers here read them at trace time — no runtime branching.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.kernels.dispatch import current_client_shard

Pytree = Any


def is_pow2(n: int) -> bool:
    """True for the client-shard counts whose halving tree aligns with
    shard boundaries (the sharded-aggregation eligibility test, §11)."""
    return n > 0 and (n & (n - 1)) == 0


def ordered_axis_sum(x: jnp.ndarray) -> jnp.ndarray:
    """Sum over the leading axis with the canonical halving association.

    Recursion on the *static* axis length, so the association is baked
    into the trace: n rows split into [0, n//2) and [n//2, n).  O(n)
    adds like any sum; the tree shape is the contract.
    """
    n = x.shape[0]
    if n == 1:
        return x[0]
    h = n // 2
    return ordered_axis_sum(x[:h]) + ordered_axis_sum(x[h:])


def _sharded_sum(x32: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """Local halving-tree partial + ordered cross-shard combine.

    ``all_gather`` stacks the D partials in mesh-axis order (shard 0
    first), and the same halving tree over that (D, ...) axis reproduces
    the top log2(D) levels of the full tree — see the module docstring
    for why this is bit-identical for power-of-two D.  A raw ``psum``
    would leave the cross-shard association to the backend.
    """
    parts = jax.lax.all_gather(ordered_axis_sum(x32), axis_name, axis=0)
    return ordered_axis_sum(parts)


def cohort_size(n_local: int) -> int:
    """The full cohort size K' given the local row count: ``n_local`` per
    shard times the active client-shard count (1 outside any context)."""
    shard = current_client_shard()
    return n_local * (shard[1] if shard is not None else 1)


def cohort_sum(x: jnp.ndarray) -> jnp.ndarray:
    """Ordered f32 sum over the (possibly client-sharded) leading axis."""
    shard = current_client_shard()
    x32 = x.astype(jnp.float32)
    if shard is None:
        return ordered_axis_sum(x32)
    return _sharded_sum(x32, shard[0])


def cohort_mean(tree: Pytree) -> Pytree:
    """Eq. 13's mean over the leading client axis, canonically associated.

    Per leaf: f32 halving-tree sum over the cohort rows divided by the
    FULL cohort size K'.  Inside a ``client_shard_axis`` context (the
    sharded aggregation program) the rows are the shard-local slice and
    the cross-shard combine follows the ordered decomposition above;
    outside (the replicated program, the async driver's host-stacked
    flush) it is the plain tree over all K' rows — the two agree bitwise.
    Output is f32, matching the historical ``jnp.mean(x.astype(f32), 0)``
    contract; callers cast back to the leaf dtype where they need to.
    """
    shard = current_client_shard()

    def mean(d):
        d32 = d.astype(jnp.float32)
        if shard is None:
            return ordered_axis_sum(d32) / d.shape[0]
        return _sharded_sum(d32, shard[0]) / (d.shape[0] * shard[1])

    return jax.tree.map(mean, tree)


def chunk_mean(tree: Pytree) -> Pytree:
    """Mean over a leading *chunk* axis of already-f32 stacked partials
    (the ``grad_chunks`` reduction in ``optim.sgd``): the same halving
    tree, no sharding context — chunk gathering is the caller's job."""
    return jax.tree.map(lambda x: ordered_axis_sum(x) / x.shape[0], tree)
