"""First-order optimizers as (init_fn, update_fn) pairs over pytrees.

update_fn(grads, state, params) -> (updates, new_state); apply with
``apply_updates``.  All moment accumulators are f32 regardless of the
parameter dtype (bf16-safe); updates are cast back to the leaf dtype.

These drive (a) the paper-faithful local SGD (Algorithm 2 uses plain SGD),
(b) the baseline FL methods, and (c) the example LM training driver.

``chunked_value_and_grad`` is the gradient entry point of the federated
local-SGD phase (DESIGN.md §11): it fixes the per-step gradient to a
canonical chunk-tree reduction so the same numbers fall out whether the
chunks run in-body or one-per-device over the mesh's data axis.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.dispatch import current_data_shard, current_grad_chunks
from repro.optim.reduce import chunk_mean

Pytree = Any
Optimizer = Tuple[Callable, Callable]


def chunked_value_and_grad(loss_fn: Callable) -> Callable:
    """``jax.value_and_grad`` with a fixed chunk-tree reduction (§11).

    The run-level ``grad_chunks = n`` knob (``FLRunConfig``, announced at
    trace time via ``repro.kernels.dispatch.grad_chunk_count``) defines
    each SGD step's semantics as: split the batch into n equal leading-
    axis chunks, take ``value_and_grad`` per chunk, combine loss and
    gradient with the canonical halving tree (``repro.optim.reduce``).
    Two trace-time execution layouts produce those semantics bitwise:

    - data axis inactive: reshape (B, ...) -> (n, B/n, ...) and compute
      the chunks in-body (unrolled — n is small and static);
    - inside a mesh engine's ``data_shard_axis`` context (the engine
      sharded the batch's dim over the data axis, so the local slice IS
      this device's chunk): compute the local chunk, all_gather the n
      partials in axis order, apply the same tree.

    Identical chunk operands + identical association => bitwise-equal
    histories between ``data=1`` and data-sharded runs at equal
    ``grad_chunks`` (tests/test_output_sharding.py).  n = 1 with no data
    context is exactly ``jax.value_and_grad`` (the seed semantics).
    """
    base = jax.value_and_grad(loss_fn)

    def fn(params, batch):
        shard = current_data_shard()
        if shard is not None:
            axis_name, n = shard
            loss, g = base(params, batch)  # local slice == this chunk
            losses = jax.lax.all_gather(
                loss.astype(jnp.float32), axis_name, axis=0)
            grads = jax.tree.map(
                lambda x: jax.lax.all_gather(
                    x.astype(jnp.float32), axis_name, axis=0), g)
            return _combine(losses, grads, params)
        n = current_grad_chunks()
        if n <= 1:
            return base(params, batch)

        def chunk(i):
            cb = jax.tree.map(lambda x: _chunk_slice(x, n, i), batch)
            return base(params, cb)

        outs = [chunk(i) for i in range(n)]
        losses = jnp.stack([l.astype(jnp.float32) for l, _ in outs])
        grads = jax.tree.map(
            lambda *xs: jnp.stack([x.astype(jnp.float32) for x in xs]),
            *[g for _, g in outs],
        )
        return _combine(losses, grads, params)

    return fn


def _chunk_slice(x, n: int, i: int):
    if x.shape[0] % n:
        raise ValueError(
            f"grad_chunks={n} must divide the local batch size "
            f"{x.shape[0]} (leading batch axis of every leaf; no padding)"
        )
    return x.reshape((n, x.shape[0] // n) + x.shape[1:])[i]


def _combine(losses, grads, params):
    """Halving-tree mean of the stacked chunk partials; gradients cast
    back to the parameter leaf dtype (the accumulators stay f32)."""
    loss = chunk_mean(losses)
    g = chunk_mean(grads)
    return loss, jax.tree.map(lambda gi, p: gi.astype(p.dtype), g, params)


def apply_updates(params: Pytree, updates: Pytree) -> Pytree:
    return jax.tree.map(
        lambda p, u: (p.astype(jnp.float32) + u.astype(jnp.float32)).astype(p.dtype),
        params,
        updates,
    )


def sgd(lr: float) -> Optimizer:
    def init(params):
        return ()

    def update(grads, state, params=None):
        del params
        return jax.tree.map(lambda g: -lr * g.astype(jnp.float32), grads), state

    return init, update


def momentum(lr: float, beta: float = 0.9, nesterov: bool = False) -> Optimizer:
    def init(params):
        return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def update(grads, state, params=None):
        del params
        m = jax.tree.map(lambda v, g: beta * v + g.astype(jnp.float32), state, grads)
        if nesterov:
            upd = jax.tree.map(lambda v, g: -lr * (beta * v + g.astype(jnp.float32)), m, grads)
        else:
            upd = jax.tree.map(lambda v: -lr * v, m)
        return upd, m

    return init, update


class AdamState(NamedTuple):
    mu: Pytree
    nu: Pytree
    count: jnp.ndarray


def adam(lr, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         weight_decay: float = 0.0) -> Optimizer:
    """lr may be a float or a schedule fn step->float."""

    def init(params):
        z = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamState(jax.tree.map(z, params), jax.tree.map(z, params),
                         jnp.zeros((), jnp.int32))

    def update(grads, state, params=None):
        count = state.count + 1
        lr_t = lr(count) if callable(lr) else lr
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                          state.mu, grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                          state.nu, grads)
        mu_hat = jax.tree.map(lambda m: m / (1 - b1**count.astype(jnp.float32)), mu)
        nu_hat = jax.tree.map(lambda v: v / (1 - b2**count.astype(jnp.float32)), nu)
        upd = jax.tree.map(lambda m, v: -lr_t * m / (jnp.sqrt(v) + eps), mu_hat, nu_hat)
        if weight_decay and params is not None:
            upd = jax.tree.map(
                lambda u, p: u - lr_t * weight_decay * p.astype(jnp.float32), upd, params
            )
        return upd, AdamState(mu, nu, count)

    return init, update


def cosine_schedule(base_lr: float, warmup: int, total: int):
    def sched(step):
        step = step.astype(jnp.float32)
        warm = base_lr * step / max(1, warmup)
        frac = jnp.clip((step - warmup) / max(1, total - warmup), 0.0, 1.0)
        cos = 0.5 * base_lr * (1.0 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, cos)

    return sched
