"""First-order optimizers as (init_fn, update_fn) pairs over pytrees.

update_fn(grads, state, params) -> (updates, new_state); apply with
``apply_updates``.  All moment accumulators are f32 regardless of the
parameter dtype (bf16-safe); updates are cast back to the leaf dtype.

These drive (a) the paper-faithful local SGD (Algorithm 2 uses plain SGD),
(b) the baseline FL methods, and (c) the example LM training driver.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

Pytree = Any
Optimizer = Tuple[Callable, Callable]


def apply_updates(params: Pytree, updates: Pytree) -> Pytree:
    return jax.tree.map(
        lambda p, u: (p.astype(jnp.float32) + u.astype(jnp.float32)).astype(p.dtype),
        params,
        updates,
    )


def sgd(lr: float) -> Optimizer:
    def init(params):
        return ()

    def update(grads, state, params=None):
        del params
        return jax.tree.map(lambda g: -lr * g.astype(jnp.float32), grads), state

    return init, update


def momentum(lr: float, beta: float = 0.9, nesterov: bool = False) -> Optimizer:
    def init(params):
        return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def update(grads, state, params=None):
        del params
        m = jax.tree.map(lambda v, g: beta * v + g.astype(jnp.float32), state, grads)
        if nesterov:
            upd = jax.tree.map(lambda v, g: -lr * (beta * v + g.astype(jnp.float32)), m, grads)
        else:
            upd = jax.tree.map(lambda v: -lr * v, m)
        return upd, m

    return init, update


class AdamState(NamedTuple):
    mu: Pytree
    nu: Pytree
    count: jnp.ndarray


def adam(lr, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         weight_decay: float = 0.0) -> Optimizer:
    """lr may be a float or a schedule fn step->float."""

    def init(params):
        z = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamState(jax.tree.map(z, params), jax.tree.map(z, params),
                         jnp.zeros((), jnp.int32))

    def update(grads, state, params=None):
        count = state.count + 1
        lr_t = lr(count) if callable(lr) else lr
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                          state.mu, grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                          state.nu, grads)
        mu_hat = jax.tree.map(lambda m: m / (1 - b1**count.astype(jnp.float32)), mu)
        nu_hat = jax.tree.map(lambda v: v / (1 - b2**count.astype(jnp.float32)), nu)
        upd = jax.tree.map(lambda m, v: -lr_t * m / (jnp.sqrt(v) + eps), mu_hat, nu_hat)
        if weight_decay and params is not None:
            upd = jax.tree.map(
                lambda u, p: u - lr_t * weight_decay * p.astype(jnp.float32), upd, params
            )
        return upd, AdamState(mu, nu, count)

    return init, update


def cosine_schedule(base_lr: float, warmup: int, total: int):
    def sched(step):
        step = step.astype(jnp.float32)
        warm = base_lr * step / max(1, warmup)
        frac = jnp.clip((step - warmup) / max(1, total - warmup), 0.0, 1.0)
        cos = 0.5 * base_lr * (1.0 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, cos)

    return sched
