"""Optimizers (mini-optax: (init, update) pairs over pytrees)."""
from repro.optim.sgd import sgd, momentum, adam, apply_updates, cosine_schedule  # noqa: F401
