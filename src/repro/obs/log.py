"""Structured logger for the observability layer (DESIGN.md §13).

One funnel for every human-facing line the stack used to ``print``
directly: the drivers' per-round progress lines, the examples' round
summaries, and the kernel-dispatch "auto resolved to" notice.  Each call
carries BOTH a preformatted human string (printed verbatim, so
format-sensitive consumers — the example-parity tests regex the
6-decimal ``loss=`` field — see exactly the bytes they always saw) and a
structured field dict that is mirrored as a JSON record into the active
trace directory when tracing is on.

Quiet mode suppresses the stdout line only; the structured record still
lands in the trace, so ``--quiet`` runs stay fully attributable.
"""
from __future__ import annotations

import json
from typing import Callable, Optional


class ObsLog:
    """Human line to stdout (unless quiet) + structured record to a sink.

    ``sink`` is a callable taking one JSON-serializable dict (the tracer
    attaches its event stream here); None drops the structured record.
    """

    def __init__(self, quiet: bool = False,
                 sink: Optional[Callable[[dict], None]] = None):
        self.quiet = quiet
        self._sink = sink

    def attach_sink(self, sink: Optional[Callable[[dict], None]]) -> None:
        self._sink = sink

    def info(self, msg: str, *, event: str = "log", logger=None, **fields):
        """Emit ``msg``.

        Default route is ``print`` (the drivers' verbose lines); passing a
        stdlib ``logger`` routes the human line there instead — used by
        the kernel-dispatch auto-resolution notice, whose consumers
        (caplog tests, library embedders) expect a ``logging`` record
        rather than stdout.  ``fields`` become the structured record.
        """
        if logger is not None:
            logger.info(msg)
        elif not self.quiet:
            print(msg)
        self._record(event, msg, fields)

    def debug(self, msg: str, *, event: str = "log", **fields):
        """Structured record only — never stdout.  For machine-facing
        notices (engine construction, cache promotion) that would
        otherwise change example output."""
        self._record(event, msg, fields)

    def _record(self, event: str, msg: str, fields: dict) -> None:
        if self._sink is None:
            return
        rec = {"k": "log", "event": event, "msg": msg}
        if fields:
            rec["fields"] = _jsonable(fields)
        self._sink(rec)


def _jsonable(obj):
    """Best-effort JSON coercion so a log call can never crash a run."""
    try:
        json.dumps(obj)
        return obj
    except (TypeError, ValueError):
        if isinstance(obj, dict):
            return {str(key): _jsonable(v) for key, v in obj.items()}
        if isinstance(obj, (list, tuple)):
            return [_jsonable(v) for v in obj]
        return repr(obj)
