"""Metrics registry: counters / gauges / histograms with a JSONL sink
(DESIGN.md §13).

Host-side only — instruments NEVER touch traced values.  The drivers
observe already-materialized host scalars/arrays (loss means, β vectors,
store byte counters), so recording is a pure read of numbers the run
produced anyway; with observability off the registry object simply never
exists and nothing is written (the zero-overhead contract).

``flush(step)`` appends one snapshot line per applied server update to
``metrics.jsonl``; the file is opened in append mode so a resumed run
continues the same series (the trace-side ``resume`` marker carries the
cut point).
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List, Optional, Sequence

import numpy as np


class Counter:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n=1):
        self.value += n


class Gauge:
    __slots__ = ("value",)

    def __init__(self):
        self.value = None

    def set(self, v):
        self.value = float(v)


class Histogram:
    """Fixed-edge histogram; right-open buckets.

    ``edges`` are ascending bucket boundaries: counts[0] holds x <
    edges[0], counts[i] holds edges[i-1] <= x < edges[i], counts[-1]
    holds x >= edges[-1] (len(counts) == len(edges) + 1).  Accepts
    scalars or arrays; accumulates count/sum/min/max alongside.
    """

    def __init__(self, edges: Sequence[float]):
        self.edges = [float(e) for e in edges]
        if self.edges != sorted(self.edges) or len(self.edges) < 1:
            raise ValueError(f"histogram edges must be ascending, got {edges}")
        self.counts = [0] * (len(self.edges) + 1)
        self.count = 0
        self.sum = 0.0
        self.min: Optional[float] = None
        self.max: Optional[float] = None

    def observe(self, x) -> None:
        arr = np.asarray(x, np.float64).ravel()
        if arr.size == 0:
            return
        idx = np.searchsorted(self.edges, arr, side="right")
        for i, n in zip(*np.unique(idx, return_counts=True)):
            self.counts[int(i)] += int(n)
        self.count += int(arr.size)
        self.sum += float(arr.sum())
        lo, hi = float(arr.min()), float(arr.max())
        self.min = lo if self.min is None else min(self.min, lo)
        self.max = hi if self.max is None else max(self.max, hi)

    def snapshot(self) -> dict:
        return {
            "edges": self.edges, "counts": list(self.counts),
            "count": self.count, "sum": self.sum,
            "min": self.min, "max": self.max,
        }


class MetricsRegistry:
    """Named counters/gauges/histograms + JSONL snapshot sink."""

    def __init__(self, path=None):
        self._path = Path(path) if path else None
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}
        self._f = None
        if self._path is not None:
            self._path.parent.mkdir(parents=True, exist_ok=True)
            self._f = open(self._path, "a")

    def counter(self, name: str) -> Counter:
        return self._counters.setdefault(name, Counter())

    def gauge(self, name: str) -> Gauge:
        return self._gauges.setdefault(name, Gauge())

    def histogram(self, name: str, edges: Sequence[float]) -> Histogram:
        h = self._histograms.get(name)
        if h is None:
            h = self._histograms[name] = Histogram(edges)
        return h

    def set_gauges(self, prefix: str, values: dict) -> None:
        """Mirror a flat numeric dict (e.g. ``CohortStore.stats()``) into
        ``prefix.key`` gauges; non-numeric values are skipped."""
        for key, v in values.items():
            if isinstance(v, (int, float)) and not isinstance(v, bool):
                self.gauge(f"{prefix}.{key}").set(v)

    def snapshot(self) -> dict:
        return {
            "counters": {n: c.value for n, c in self._counters.items()},
            "gauges": {n: g.value for n, g in self._gauges.items()},
            "histograms": {n: h.snapshot()
                           for n, h in self._histograms.items()},
        }

    def flush(self, step=None, **extra) -> None:
        """Append one snapshot line (no-op without a sink path)."""
        if self._f is None:
            return
        line = {"step": step, **extra, **self.snapshot()}
        self._f.write(json.dumps(line) + "\n")
        self._f.flush()

    def close(self) -> None:
        if self._f is not None:
            self._f.close()
            self._f = None


def read_metrics(path) -> List[dict]:
    """Parse a metrics.jsonl file back into snapshot dicts."""
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out
