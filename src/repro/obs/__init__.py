"""repro.obs — zero-overhead-when-disabled observability (DESIGN.md §13).

Three instruments behind one ``Obs`` facade:

- ``Tracer`` (``repro.obs.trace``): nested wall-clock spans + discrete
  sim-time client tracks, JSONL event stream, Chrome-trace/Perfetto
  export, checkpoint-style fingerprint stamping with resume-append.
- ``MetricsRegistry`` (``repro.obs.metrics``): counters/gauges/
  histograms with a per-round JSONL snapshot sink.
- ``ObsLog`` (``repro.obs.log``): the structured logger every ad-hoc
  driver print routes through (quiet mode suppresses stdout only).

The hard contract (tests/test_obs_invariance.py): observability NEVER
touches traced values.  Every instrument reads host-side numbers the run
already produced; the only on-path effect of enabling it is wall-clock
(``timed`` blocks between phases so span durations are honest).  With it
off (``FLRunConfig.obs = None``, the default) the drivers hold the
shared ``NOOP`` facade: no files, no objects, no extra synchronization —
training histories are bitwise identical to an uninstrumented build.

Levels: ``off`` < ``round`` (round spans + metrics) < ``phase``
(+ per-phase spans with block-until-ready boundaries) < ``kernel``
(+ ``jax.profiler`` annotations around kernel launches, §9).
"""
from __future__ import annotations

import contextlib
import time
from dataclasses import dataclass
from pathlib import Path
from typing import Any, Optional

from repro.obs.log import ObsLog
from repro.obs.metrics import Histogram, MetricsRegistry, read_metrics
from repro.obs.trace import Tracer, export_chrome, read_events

__all__ = [
    "OBS_LEVELS", "ObsConfig", "Obs", "NOOP", "make_obs", "as_obs_config",
    "get_obs", "ObsLog", "MetricsRegistry", "Histogram", "Tracer",
    "export_chrome", "read_events", "read_metrics",
    "LEVEL_OFF", "LEVEL_ROUND", "LEVEL_PHASE", "LEVEL_KERNEL",
]

OBS_LEVELS = ("off", "round", "phase", "kernel")
LEVEL_OFF, LEVEL_ROUND, LEVEL_PHASE, LEVEL_KERNEL = range(4)


@dataclass(frozen=True)
class ObsConfig:
    """Observability knobs, nested under ``FLRunConfig.obs``.

    ``trace_dir``: event-stream directory ("" = no tracing).  The drivers
    stamp it with the run's config fingerprint (``meta.json``); reopening
    with a matching fingerprint appends (a ``resume`` marker event marks
    the cut), a mismatch raises — mirroring checkpoint-restore rejection.
    Deliberately NOT part of the checkpoint fingerprint itself: resuming
    a run with tracing newly enabled (or disabled) is always allowed.

    ``metrics``: metrics.jsonl path; "" defaults to
    ``<trace_dir>/metrics.jsonl`` when tracing (and to off otherwise).

    ``level``: one of ``OBS_LEVELS`` — see the module docstring.

    ``quiet``: suppress the drivers' stdout progress lines (structured
    records still land in the trace).

    ``xla_profile``: 0-based round/version index to wrap in a
    ``jax.profiler`` trace window (dumped under ``<trace_dir>/xla``);
    -1 = off.  Round 1 is the first post-compile round.
    """

    trace_dir: str = ""
    metrics: str = ""
    level: str = "phase"
    quiet: bool = False
    xla_profile: int = -1

    def __post_init__(self):
        if self.level not in OBS_LEVELS:
            raise ValueError(
                f"obs level must be one of {OBS_LEVELS}, got {self.level!r}"
            )


def as_obs_config(obs) -> Optional[ObsConfig]:
    """Resolve ``FLRunConfig.obs``: None passes through (disabled)."""
    if obs is None or isinstance(obs, ObsConfig):
        return obs
    if isinstance(obs, dict):
        return ObsConfig(**obs)
    raise TypeError(
        f"obs must be None, an ObsConfig, or a kwargs dict; got "
        f"{type(obs).__name__}"
    )


_NULL_CTX = contextlib.nullcontext()


class Obs:
    """The facade the drivers thread through every layer.

    Constructed eagerly (``make_obs``) so the level/quiet knobs resolve
    at federation construction; file handles open in ``open()``, which
    the drivers call once the run fingerprint is known.  The shared
    ``NOOP`` instance (``Obs(None)``) is what a federation without an
    ``ObsConfig`` holds: every method is a cheap guard-and-return.
    """

    def __init__(self, cfg: Optional[ObsConfig]):
        self.cfg = cfg
        self.level = LEVEL_OFF
        self.enabled = False
        if cfg is not None and cfg.level != "off" and (
                cfg.trace_dir or cfg.metrics):
            self.level = OBS_LEVELS.index(cfg.level)
            self.enabled = True
        self.log = ObsLog(quiet=bool(cfg and cfg.quiet))
        self.tracer: Optional[Tracer] = None
        self.metrics: Optional[MetricsRegistry] = None
        # last registry snapshot, stashed by close() so callers that want
        # the final numbers (the bench harness embedding them in
        # BENCH_*.json) don't have to re-read metrics.jsonl
        self.final_metrics: Optional[dict] = None
        self._xla_active = False

    # -- lifecycle ---------------------------------------------------------

    def open(self, fingerprint: Optional[dict] = None) -> "Obs":
        """Open the sinks (idempotent).  ``fingerprint`` is stamped into
        (and checked against) the trace's ``meta.json``."""
        if not self.enabled:
            return self
        if self.cfg.trace_dir and self.tracer is None:
            self.tracer = Tracer(self.cfg.trace_dir, fingerprint=fingerprint)
            self.log.attach_sink(self.tracer.sink)
            _set_global(self)
        metrics_path = self.cfg.metrics or (
            str(Path(self.cfg.trace_dir) / "metrics.jsonl")
            if self.cfg.trace_dir else "")
        if metrics_path and self.metrics is None:
            self.metrics = MetricsRegistry(metrics_path)
        return self

    def close(self) -> None:
        """Flush + close sinks and export the Chrome trace (idempotent;
        the exported ``trace.json`` is regenerated from the FULL event
        stream, so a resumed run exports one combined timeline)."""
        if self.metrics is not None:
            self.final_metrics = self.metrics.snapshot()
            self.metrics.close()
            self.metrics = None
        if self.tracer is not None:
            self.log.attach_sink(None)
            self.tracer.close()
            export_chrome(self.tracer.dir)
            self.tracer = None

    # -- recording ---------------------------------------------------------

    def span(self, name: str, **kw):
        """Nested wall-clock span at ``round`` level and above."""
        if self.tracer is None or self.level < LEVEL_ROUND:
            return _NULL_CTX
        return self.tracer.span(name, **kw)

    def timed(self, name: str, fn, *args, sync: bool = True, **meta):
        """Run ``fn(*args)`` under a phase span (level ``phase``+).

        ``sync`` blocks on the outputs so the span measures the phase's
        actual device time, not its dispatch time — the documented
        wall-clock-only cost of enabling phase tracing.  ``sync=False``
        is for phases whose deferral IS the design (the store's
        overlapped d2h scatter).  Below phase level this is exactly
        ``fn(*args)``.
        """
        if self.tracer is None or self.level < LEVEL_PHASE:
            return fn(*args)
        ts = time.time_ns() // 1000
        t0 = time.perf_counter_ns()
        out = fn(*args)
        if sync:
            import jax
            out = jax.block_until_ready(out)
        self.tracer.complete(name, ts, (time.perf_counter_ns() - t0) // 1000,
                             **meta)
        return out

    def event(self, name: str, **kw) -> None:
        if self.tracer is not None and self.level >= LEVEL_ROUND:
            self.tracer.event(name, **kw)

    def client_span(self, client: int, name: str, sim0: float, sim1: float,
                    **args) -> None:
        if self.tracer is not None and self.level >= LEVEL_ROUND:
            self.tracer.client_span(client, name, sim0, sim1, **args)

    def flush_metrics(self, step=None, **extra) -> None:
        if self.metrics is not None:
            self.metrics.flush(step=step, **extra)

    def flush(self) -> None:
        """Push buffered trace events to disk (the drivers call this per
        round so a crashed run still leaves a readable timeline)."""
        if self.tracer is not None:
            self.tracer.flush()

    # -- jax.profiler window (--xla-profile) -------------------------------

    def xla_round_start(self, t: int) -> None:
        if (self._xla_active or self.tracer is None
                or self.cfg.xla_profile < 0 or t != self.cfg.xla_profile):
            return
        import jax
        try:
            jax.profiler.start_trace(str(self.tracer.dir / "xla"))
            self._xla_active = True
            self.event("xla_profile_start", round=t)
        except Exception as e:  # profiler backend may be absent on CPU
            self.log.debug(f"xla profiler unavailable: {e}",
                           event="xla_profile_error")

    def xla_round_end(self, t: int) -> None:
        if not self._xla_active:
            return
        import jax
        self._xla_active = False
        jax.profiler.stop_trace()
        self.event("xla_profile_stop", round=t)


NOOP = Obs(None)

_GLOBAL: Obs = NOOP


def _set_global(obs: Obs) -> None:
    global _GLOBAL
    _GLOBAL = obs


def get_obs() -> Obs:
    """The most recently opened tracing facade (NOOP otherwise) — the
    hook layers without a driver handle (kernel dispatch) report to."""
    return _GLOBAL


def make_obs(obs) -> Obs:
    """``FLRunConfig.obs`` -> an ``Obs`` facade (shared NOOP when None)."""
    cfg = as_obs_config(obs)
    if cfg is None:
        return NOOP
    return Obs(cfg)
