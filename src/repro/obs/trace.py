"""Tracer: nested wall-clock/sim-time spans with a Chrome-trace exporter
(DESIGN.md §13).

Event stream semantics
----------------------

The tracer appends newline-delimited JSON records to
``<trace_dir>/events.jsonl``; ``meta.json`` beside it stamps the run's
config fingerprint (the same facets the checkpoint manifest stamps).
Re-opening an existing trace directory with a MATCHING fingerprint
appends — with a ``resume`` marker event at the cut — instead of
clobbering; a mismatched fingerprint raises, mirroring the checkpoint
restore rejection (a trace mixing two configs is not a timeline).

Two clocks, one file:

- **wall clock** — span ``ts`` is epoch microseconds
  (``time.time_ns() // 1000``), so appended segments from a resumed
  process stay globally monotonic; ``dur`` comes from ``perf_counter``.
- **sim time** — the discrete-event simulated clock of the async
  scheduler (and the sync driver's straggler model).  ``client_span``
  records an interval purely in sim seconds; server records may carry a
  ``sim`` annotation alongside their wall timestamp.

``export_chrome`` renders both as one Chrome-trace/Perfetto JSON
(``trace.json``): wall-clock records as process "server (wall clock)"
with one thread per track, sim-time records as process "clients (sim
time)" with one thread per client — load either in Perfetto or
chrome://tracing.  Sim seconds map to trace microseconds 1:1e6, so a
sim-second reads as a second in the viewer.
"""
from __future__ import annotations

import contextlib
import json
import time
from pathlib import Path
from typing import List, Optional

SCHEMA_VERSION = 1


def _wall_us() -> int:
    return time.time_ns() // 1000


class Tracer:
    """Append-mode JSONL event stream under ``trace_dir``."""

    def __init__(self, trace_dir, fingerprint: Optional[dict] = None):
        self.dir = Path(trace_dir)
        self.dir.mkdir(parents=True, exist_ok=True)
        meta_path = self.dir / "meta.json"
        events = self.dir / "events.jsonl"
        resuming = meta_path.exists()
        if resuming:
            meta = json.loads(meta_path.read_text())
            if fingerprint is not None and meta.get("fingerprint") != fingerprint:
                raise ValueError(
                    f"trace at {self.dir} was recorded with fingerprint "
                    f"{meta.get('fingerprint')}, but this run is configured "
                    f"with {fingerprint}; appending across a config change "
                    "would mix two incomparable timelines (use a fresh "
                    "--trace-dir)"
                )
        else:
            meta = {"schema": SCHEMA_VERSION, "fingerprint": fingerprint}
            meta_path.write_text(json.dumps(meta, indent=1, default=str))
        self._f = open(events, "a")
        self._stack: List[dict] = []
        if resuming:
            self.event("resume", cat="marker")

    # -- record emission ---------------------------------------------------

    def _write(self, rec: dict) -> None:
        self._f.write(json.dumps(rec) + "\n")

    def sink(self, rec: dict) -> None:
        """Raw-record sink (the structured-log mirror attaches here)."""
        rec = dict(rec)
        rec.setdefault("ts", _wall_us())
        self._write(rec)

    @contextlib.contextmanager
    def span(self, name: str, track: str = "server",
             sim: Optional[float] = None, **args):
        """Nested wall-clock span (context manager); ``depth`` is the
        nesting level at entry, recorded so consumers need not rebuild
        the stack from timestamps."""
        rec = {"k": "span", "name": name, "track": track,
               "ts": _wall_us(), "depth": len(self._stack)}
        if sim is not None:
            rec["sim"] = float(sim)
        if args:
            rec["args"] = args
        self._stack.append(rec)
        t0 = time.perf_counter_ns()
        try:
            yield rec
        finally:
            rec["dur"] = (time.perf_counter_ns() - t0) // 1000
            self._stack.pop()
            self._write(rec)

    def complete(self, name: str, ts_us: int, dur_us: int,
                 track: str = "server", sim: Optional[float] = None,
                 **args) -> None:
        """Pre-timed wall-clock span (the driver's phase timer)."""
        rec = {"k": "span", "name": name, "track": track,
               "ts": int(ts_us), "dur": int(dur_us),
               "depth": len(self._stack)}
        if sim is not None:
            rec["sim"] = float(sim)
        if args:
            rec["args"] = args
        self._write(rec)

    def event(self, name: str, cat: str = "event", track: str = "server",
              sim: Optional[float] = None, **args) -> None:
        """Instant event on the wall clock (optionally sim-annotated)."""
        rec = {"k": "ev", "name": name, "cat": cat, "track": track,
               "ts": _wall_us()}
        if sim is not None:
            rec["sim"] = float(sim)
        if args:
            rec["args"] = args
        self._write(rec)

    def client_span(self, client: int, name: str, sim0: float, sim1: float,
                    **args) -> None:
        """Sim-time interval on a per-client track (async lifecycle)."""
        rec = {"k": "cspan", "name": name, "client": int(client),
               "sim0": float(sim0), "sim1": float(sim1), "ts": _wall_us()}
        if args:
            rec["args"] = args
        self._write(rec)

    def flush(self) -> None:
        self._f.flush()

    def close(self) -> None:
        self._f.flush()
        self._f.close()


# ---------------------------------------------------------------------------
# Chrome-trace / Perfetto export
# ---------------------------------------------------------------------------

_WALL_PID = 1
_SIM_PID = 2


def read_events(trace_dir) -> List[dict]:
    out = []
    with open(Path(trace_dir) / "events.jsonl") as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


def export_chrome(trace_dir, out_path=None) -> Path:
    """Render ``events.jsonl`` as Chrome-trace JSON (``trace.json``).

    Wall-clock spans/events land under pid 1 with one tid per track;
    sim-time client spans land under pid 2 with tid = client id (sim
    seconds scaled to trace µs); server records carrying a ``sim``
    annotation are mirrored as instants onto pid 2's "server" thread, so
    dispatch/flush structure lines up with the client tracks.
    """
    trace_dir = Path(trace_dir)
    events = read_events(trace_dir)
    out: List[dict] = [
        {"ph": "M", "pid": _WALL_PID, "name": "process_name",
         "args": {"name": "server (wall clock)"}},
        {"ph": "M", "pid": _SIM_PID, "name": "process_name",
         "args": {"name": "clients (sim time)"}},
        {"ph": "M", "pid": _SIM_PID, "tid": 0, "name": "thread_name",
         "args": {"name": "server (sim)"}},
    ]
    tids = {}

    def tid_of(track: str) -> int:
        tid = tids.get(track)
        if tid is None:
            tid = tids[track] = len(tids) + 1
            out.append({"ph": "M", "pid": _WALL_PID, "tid": tid,
                        "name": "thread_name", "args": {"name": track}})
        return tid

    clients = set()
    for rec in events:
        kind = rec.get("k")
        args = dict(rec.get("args", {}))
        if "sim" in rec:
            args["sim"] = rec["sim"]
        if kind == "span":
            out.append({"ph": "X", "pid": _WALL_PID,
                        "tid": tid_of(rec.get("track", "server")),
                        "name": rec["name"], "cat": "wall",
                        "ts": rec["ts"], "dur": rec.get("dur", 0),
                        "args": args})
            if "sim" in rec:
                out.append({"ph": "i", "pid": _SIM_PID, "tid": 0, "s": "t",
                            "name": rec["name"], "cat": "sim",
                            "ts": int(rec["sim"] * 1e6), "args": args})
        elif kind == "ev":
            out.append({"ph": "i", "pid": _WALL_PID,
                        "tid": tid_of(rec.get("track", "server")),
                        "s": "t", "name": rec["name"],
                        "cat": rec.get("cat", "event"),
                        "ts": rec["ts"], "args": args})
            if "sim" in rec:
                out.append({"ph": "i", "pid": _SIM_PID, "tid": 0, "s": "t",
                            "name": rec["name"], "cat": "sim",
                            "ts": int(rec["sim"] * 1e6), "args": args})
        elif kind == "cspan":
            c = int(rec["client"])
            if c not in clients:
                clients.add(c)
                out.append({"ph": "M", "pid": _SIM_PID, "tid": c + 1,
                            "name": "thread_name",
                            "args": {"name": f"client {c}"}})
            out.append({"ph": "X", "pid": _SIM_PID, "tid": c + 1,
                        "name": rec["name"], "cat": "sim",
                        "ts": int(rec["sim0"] * 1e6),
                        "dur": max(int((rec["sim1"] - rec["sim0"]) * 1e6), 1),
                        "args": args})
        # "log" records are trace-dir artifacts, not timeline entries

    path = Path(out_path) if out_path else trace_dir / "trace.json"
    path.write_text(json.dumps(
        {"traceEvents": out, "displayTimeUnit": "ms"}))
    return path
