"""Pytree arithmetic helpers.

pFedSOP operates on *gradient-update pytrees* (same structure as the model
parameters).  All reductions here return f32 scalars regardless of leaf dtype
so the Gompertz / Sherman-Morrison scalar math is numerically stable even for
bf16 parameter trees.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_dot(a, b):
    """Global dot product <a, b> across all leaves, f32 accumulation."""
    leaves_a = jax.tree.leaves(a)
    leaves_b = jax.tree.leaves(b)
    parts = [
        jnp.sum(x.astype(jnp.float32) * y.astype(jnp.float32))
        for x, y in zip(leaves_a, leaves_b)
    ]
    return jnp.sum(jnp.stack(parts)) if parts else jnp.float32(0.0)


def tree_sqnorm(a):
    """Global squared L2 norm, f32 accumulation."""
    return tree_dot(a, a)


def tree_norm(a):
    return jnp.sqrt(tree_sqnorm(a))


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a, b):
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(s, a):
    """s * a with s a scalar (broadcast, cast back to leaf dtype)."""
    return jax.tree.map(lambda x: (s * x.astype(jnp.float32)).astype(x.dtype), a)


def tree_axpy(s, x, y):
    """y + s * x, elementwise over the tree (cast back to y's leaf dtype)."""
    return jax.tree.map(
        lambda xi, yi: (yi.astype(jnp.float32) + s * xi.astype(jnp.float32)).astype(yi.dtype),
        x,
        y,
    )


def tree_lerp(beta, a, b):
    """(1-beta)*a + beta*b elementwise over the tree."""
    return jax.tree.map(
        lambda x, y: (
            (1.0 - beta) * x.astype(jnp.float32) + beta * y.astype(jnp.float32)
        ).astype(x.dtype),
        a,
        b,
    )


def tree_zeros_like(a):
    return jax.tree.map(jnp.zeros_like, a)


def tree_size(a):
    """Total number of scalar parameters."""
    return sum(int(x.size) for x in jax.tree.leaves(a))


def tree_bytes(a):
    return sum(int(x.size) * x.dtype.itemsize for x in jax.tree.leaves(a))


def tree_cast(a, dtype):
    return jax.tree.map(lambda x: x.astype(dtype), a)


def tree_where(pred, a, b):
    """Select tree a where pred else b (pred is a scalar bool)."""
    return jax.tree.map(lambda x, y: jnp.where(pred, x, y), a, b)


def tree_flatten_to_vector(a):
    """Concatenate all leaves into one f32 vector (small models only)."""
    leaves = jax.tree.leaves(a)
    return jnp.concatenate([x.astype(jnp.float32).reshape(-1) for x in leaves])


def tree_unflatten_from_vector(vec, template):
    """Inverse of tree_flatten_to_vector given a template tree."""
    leaves, treedef = jax.tree.flatten(template)
    out = []
    offset = 0
    for leaf in leaves:
        n = int(leaf.size)
        out.append(vec[offset : offset + n].reshape(leaf.shape).astype(leaf.dtype))
        offset += n
    return jax.tree.unflatten(treedef, out)
