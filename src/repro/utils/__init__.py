from repro.utils import pytree  # noqa: F401
