"""Checkpointing: pytree save/restore with a JSON manifest (offline-safe;
no orbax dependency).

Layout:  <dir>/step_<N>/arrays.npz + manifest.json
The manifest records the flattened key paths and dtypes so restore can
rebuild the exact pytree structure (dicts, tuples, NamedTuples degrade to
their dict/tuple forms via jax.tree flattening against a template).

Used by the FL drivers (server state + per-client personalized models) and
the LM example trainer.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_names(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "/".join(
            str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k))))
            for k in path
        )
        out.append((name, leaf))
    return out


def save_checkpoint(ckpt_dir, step: int, tree: Any, extra: Optional[dict] = None):
    d = Path(ckpt_dir) / f"step_{step:08d}"
    d.mkdir(parents=True, exist_ok=True)
    named = _flatten_with_names(tree)
    arrays = {f"a{i}": np.asarray(leaf) for i, (_, leaf) in enumerate(named)}
    np.savez(d / "arrays.npz", **arrays)
    manifest = {
        "step": step,
        "names": [n for n, _ in named],
        "dtypes": [str(np.asarray(l).dtype) for _, l in named],
        "extra": extra or {},
    }
    (d / "manifest.json").write_text(json.dumps(manifest, indent=1))
    return str(d)


def latest_step(ckpt_dir) -> Optional[int]:
    d = Path(ckpt_dir)
    if not d.exists():
        return None
    steps = sorted(int(p.name.split("_")[1]) for p in d.glob("step_*"))
    return steps[-1] if steps else None


def load_checkpoint(ckpt_dir, template: Any, step: Optional[int] = None):
    """Restore into the structure of ``template``.  Returns (tree, extra)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = Path(ckpt_dir) / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    data = np.load(d / "arrays.npz")

    named_t = _flatten_with_names(template)
    by_name = {n: data[f"a{i}"] for i, n in enumerate(manifest["names"])}
    assert [n for n, _ in named_t] == manifest["names"], (
        "checkpoint/template structure mismatch"
    )
    leaves = [
        jax.numpy.asarray(by_name[n]).astype(l.dtype) if hasattr(l, "dtype")
        else by_name[n]
        for n, l in named_t
    ]
    flat, treedef = jax.tree_util.tree_flatten(template)
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest["extra"]
