"""Checkpointing: pytree save/restore with a JSON manifest (offline-safe;
no orbax dependency).

Layout:  <dir>/step_<N>/arrays.npz + manifest.json
The manifest records the flattened key paths and dtypes so restore can
rebuild the exact pytree structure (dicts, tuples, NamedTuples degrade to
their dict/tuple forms via jax.tree flattening against a template).

Used by the FL drivers (server state + per-client personalized models) and
the LM example trainer.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_names(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "/".join(
            str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k))))
            for k in path
        )
        out.append((name, leaf))
    return out


def save_checkpoint(ckpt_dir, step: int, tree: Any, extra: Optional[dict] = None):
    d = Path(ckpt_dir) / f"step_{step:08d}"
    d.mkdir(parents=True, exist_ok=True)
    named = _flatten_with_names(tree)
    arrays = {f"a{i}": np.asarray(leaf) for i, (_, leaf) in enumerate(named)}
    np.savez(d / "arrays.npz", **arrays)
    manifest = {
        "step": step,
        "names": [n for n, _ in named],
        "dtypes": [str(np.asarray(l).dtype) for _, l in named],
        "extra": extra or {},
    }
    (d / "manifest.json").write_text(json.dumps(manifest, indent=1))
    return str(d)


def read_manifest(ckpt_dir, step: Optional[int] = None) -> dict:
    """Peek at a checkpoint's manifest without loading arrays.

    The federation drivers need this before ``load_checkpoint``: the async
    driver's checkpoint tree has variable-count subtrees (in-flight work,
    aggregation buffer) whose presence is recorded in ``extra``, so the
    restore template must be built after reading the counts.
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = Path(ckpt_dir) / f"step_{step:08d}"
    return json.loads((d / "manifest.json").read_text())


def rng_state_tree(rng: "np.random.RandomState") -> dict:
    """Snapshot a host RandomState as a checkpointable array pytree.

    The MT19937 state tuple from ``rng.get_state()`` becomes plain numpy
    arrays (npz round-trips them exactly), so a restored federation resumes
    the participation/batch sampling stream bit-for-bit.
    """
    kind, keys, pos, has_gauss, cached = rng.get_state()
    if kind != "MT19937":
        raise ValueError(f"unsupported bit generator {kind!r} (expected MT19937)")
    return {
        "keys": np.asarray(keys, np.uint32),
        "pos": np.asarray(pos, np.int64),
        "has_gauss": np.asarray(has_gauss, np.int64),
        "cached_gaussian": np.asarray(cached, np.float64),
    }


def restore_rng_state(rng: "np.random.RandomState", tree: dict) -> None:
    """Inverse of ``rng_state_tree`` (accepts jnp or np leaves)."""
    rng.set_state((
        "MT19937",
        np.asarray(tree["keys"], np.uint32),
        int(tree["pos"]),
        int(tree["has_gauss"]),
        float(tree["cached_gaussian"]),
    ))


def latest_step(ckpt_dir) -> Optional[int]:
    d = Path(ckpt_dir)
    if not d.exists():
        return None
    steps = sorted(int(p.name.split("_")[1]) for p in d.glob("step_*"))
    return steps[-1] if steps else None


def load_checkpoint(ckpt_dir, template: Any, step: Optional[int] = None):
    """Restore into the structure of ``template``.  Returns (tree, extra)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = Path(ckpt_dir) / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    data = np.load(d / "arrays.npz")

    named_t = _flatten_with_names(template)
    by_name = {n: data[f"a{i}"] for i, n in enumerate(manifest["names"])}
    assert [n for n, _ in named_t] == manifest["names"], (
        "checkpoint/template structure mismatch"
    )
    leaves = []
    for n, l in named_t:
        arr = by_name[n]
        if isinstance(l, (np.ndarray, np.generic)):
            # host-side state (RNG words, histories, masks, scheduler
            # arrays): stay in numpy — round-tripping through jnp would
            # truncate float64/int64 on x64-disabled jax and return
            # read-only buffers
            leaves.append(np.array(arr, dtype=l.dtype))
        elif hasattr(l, "dtype"):
            leaves.append(jax.numpy.asarray(arr).astype(l.dtype))
        else:
            leaves.append(arr)
    flat, treedef = jax.tree_util.tree_flatten(template)
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest["extra"]
