"""Production mesh definitions (TPU v5e target).

Single pod: 256 chips as (data=16, model=16).
Multi-pod:  2 pods x 256 chips as (pod=2, data=16, model=16); the ``pod``
axis is the FL-cohort axis - each pod runs one client's local phase, and
the only cross-pod collective is the round-boundary all-reduce of the
local gradient updates (DESIGN.md §3).

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before any jax initialisation).
"""
from __future__ import annotations

import numpy as np

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devices)} - run under "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 (see dryrun.py)"
        )
    return jax.make_mesh(shape, axes, devices=devices[:n])


def make_host_mesh():
    """Degenerate 1x1 mesh for CPU smoke runs of the sharded step code."""
    return jax.make_mesh((1, 1), ("data", "model"), devices=jax.devices()[:1])


def make_client_mesh(n_shards: int, axis_name: str = "clients"):
    """1-D mesh over the FL participating-client axis (DESIGN.md §3).

    Used by ``repro.fl.engine.ShardMapBackend`` to split a round's K'
    clients across local devices; the single-axis layout keeps the client
    phase embarrassingly parallel and confines cross-device traffic to the
    round-boundary aggregation psum.
    """
    devices = jax.devices()
    if len(devices) < n_shards:
        raise RuntimeError(
            f"client mesh needs {n_shards} devices, found {len(devices)} - "
            "run under XLA_FLAGS=--xla_force_host_platform_device_count=N "
            "for CPU multi-device simulation"
        )
    return jax.make_mesh((n_shards,), (axis_name,), devices=devices[:n_shards])
