"""Mesh layer: role-named mesh specs + resolution (DESIGN.md §11).

Every driver — the §3/§11 federation engines, the launch/serve paths and
the §6 dry-run — builds its device mesh from one abstraction:

  ``MeshSpec``      a frozen description of axis names/sizes plus the *roles*
                    they play: the client axis (the participating-client /
                    FL-cohort axis the engines shard_map over), the data
                    axis (batch parallelism) and the model axis (Megatron
                    tensor parallelism + the §9 model-sharded update kernel).
  ``resolve_mesh``  MeshSpec -> jax.sharding.Mesh, with device-count
                    validation and the XLA_FLAGS hint in the error.
  ``parse_mesh``    CLI grammar ("clients[:N]" | "host" | "pod:DxM" |
                    "pods:PxDxM") -> MeshSpec, for ``--mesh`` flags.

Shipped layouts (TPU v5e target, all shapes parameterizable so reduced
meshes run on forced host devices — e.g. ``pods:2x2x2`` on 8):

  client mesh      1-D (clients,): the §3 engine layout; embarrassingly
                   parallel client phase, cross-device traffic confined to
                   the round-boundary collective.
  single pod       256 chips as (data=16, model=16).
  multi-pod        2 pods x 256 chips as (pod=2, data=16, model=16); the
                   ``pod`` axis is the FL-cohort axis — each pod runs an
                   equal contiguous slice of the round's participating-client
                   cohort (the cohort-sharded layout of DESIGN.md §11; the
                   per-client local phase replicates over (data, model)
                   inside a pod except the §9 model-sharded round-start
                   update), and the cross-pod collective is the
                   round-boundary aggregation of the local updates.
  host mesh        degenerate 1x1 (data, model) for CPU smoke runs.

Defined as FUNCTIONS (and a pure-data spec) so importing this module never
touches jax device state (the dry-run must set XLA_FLAGS before any jax
initialisation).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np


@dataclass(frozen=True)
class MeshSpec:
    """Axis names/sizes plus role annotations; pure data, no jax state.

    ``client_axis``/``data_axis``/``model_axis`` name which mesh axis plays
    each role (or None when the role is absent — e.g. the 1-D client mesh
    has no model axis, the single-pod mesh no client axis).  Roles are what
    the consumers key on: ``repro.fl.engine.MeshBackend`` shard_maps the
    participating-client axis over ``client_axis``, ``launch/sharding.py``
    rules shard params over ``model_axis`` and batches over ``data_axis``.
    """

    shape: Tuple[int, ...]
    axes: Tuple[str, ...]
    client_axis: Optional[str] = None
    data_axis: Optional[str] = None
    model_axis: Optional[str] = None

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"shape {self.shape} / axes {self.axes} length mismatch")
        if len(set(self.axes)) != len(self.axes):
            raise ValueError(f"duplicate axis names in {self.axes}")
        for s, a in zip(self.shape, self.axes):
            if s < 1:
                raise ValueError(f"axis {a!r} has non-positive size {s}")
        for role, name in [("client_axis", self.client_axis),
                           ("data_axis", self.data_axis),
                           ("model_axis", self.model_axis)]:
            if name is not None and name not in self.axes:
                raise ValueError(
                    f"{role}={name!r} is not a mesh axis (axes: {self.axes})")

    # -- role-keyed sizes --------------------------------------------------

    def size(self, axis: Optional[str]) -> int:
        """Size of a named axis; 1 for None (an absent role is a size-1
        degenerate axis as far as divisibility/sharding math goes)."""
        if axis is None:
            return 1
        return self.shape[self.axes.index(axis)]

    @property
    def n_devices(self) -> int:
        return int(np.prod(self.shape))

    @property
    def client_size(self) -> int:
        return self.size(self.client_axis)

    @property
    def data_size(self) -> int:
        return self.size(self.data_axis)

    @property
    def model_size(self) -> int:
        return self.size(self.model_axis)

    def signature(self) -> str:
        """Stable id for program-cache keys and logs (RoundPrograms caches
        phase programs per (cohort size, mesh signature) — DESIGN.md §11)."""
        dims = ",".join(f"{a}={s}" for a, s in zip(self.axes, self.shape))
        roles = ",".join(
            f"{r}:{n}" for r, n in [("client", self.client_axis),
                                    ("data", self.data_axis),
                                    ("model", self.model_axis)] if n)
        return f"{dims}[{roles}]" if roles else f"{dims}[]"

    # -- shipped layouts ---------------------------------------------------

    @staticmethod
    def clients(n_shards: int, axis_name: str = "clients") -> "MeshSpec":
        """1-D mesh over the FL participating-client axis (DESIGN.md §3)."""
        return MeshSpec((n_shards,), (axis_name,), client_axis=axis_name)

    @staticmethod
    def host() -> "MeshSpec":
        """Degenerate 1x1 (data, model) mesh for CPU smoke runs."""
        return MeshSpec((1, 1), ("data", "model"),
                        data_axis="data", model_axis="model")

    @staticmethod
    def single_pod(data: int = 16, model: int = 16) -> "MeshSpec":
        """One pod: (data, model) tensor/batch parallelism, no client axis."""
        return MeshSpec((data, model), ("data", "model"),
                        data_axis="data", model_axis="model")

    @staticmethod
    def multi_pod(pods: int = 2, data: int = 16, model: int = 16) -> "MeshSpec":
        """(pod, data, model): ``pod`` is the FL-cohort (client-role) axis."""
        return MeshSpec((pods, data, model), ("pod", "data", "model"),
                        client_axis="pod", data_axis="data",
                        model_axis="model")


_MESH_GRAMMAR = (
    "mesh spec grammar: 'clients' | 'clients:N' (1-D client mesh, N shards, "
    "0/omitted = auto) | 'host' (1x1 data,model) | 'pod:DxM' (single pod) | "
    "'pods:PxDxM' (multi-pod; pod = client-role axis)"
)


def parse_mesh(spec: str) -> MeshSpec:
    """Parse a ``--mesh`` CLI string into a MeshSpec (see _MESH_GRAMMAR).

    ``clients:0``/``clients`` returns a client spec with shape ``(0,)``
    sentinel meaning "auto shard count" — callers (the engine factory)
    replace it with ``resolve_shards`` before touching devices.
    """
    s = spec.strip().lower()
    head, _, tail = s.partition(":")
    try:
        if head == "clients":
            n = int(tail) if tail else 0
            if n < 0:
                raise ValueError
            # size-0 sentinel bypasses validation via direct construction
            return MeshSpec.clients(max(n, 1)) if n else _auto_clients_spec()
        if head == "host" and not tail:
            return MeshSpec.host()
        if head == "pod":
            d, m = (int(x) for x in tail.split("x"))
            return MeshSpec.single_pod(d, m)
        if head == "pods":
            p, d, m = (int(x) for x in tail.split("x"))
            return MeshSpec.multi_pod(p, d, m)
    except (ValueError, TypeError) as e:
        raise ValueError(f"bad mesh spec {spec!r}; {_MESH_GRAMMAR}") from e
    raise ValueError(f"unknown mesh spec {spec!r}; {_MESH_GRAMMAR}")


class _AutoClients(MeshSpec):
    """Marker subclass: 1-D client mesh whose shard count is resolved from
    (K', local devices) by the engine factory (``clients``/``clients:0``)."""


def _auto_clients_spec() -> MeshSpec:
    return _AutoClients((1,), ("clients",), client_axis="clients")


def is_auto_clients(spec: MeshSpec) -> bool:
    return isinstance(spec, _AutoClients)


def resolve_mesh(spec: MeshSpec):
    """MeshSpec -> jax.sharding.Mesh over the first n_devices local devices.

    The only function here that touches jax device state; raises with the
    forced-host-device hint when the host is short on devices.
    """
    import jax  # deferred: importing this module must not init jax

    devices = jax.devices()
    n = spec.n_devices
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {spec.signature()} needs {n} devices, found {len(devices)}"
            f" - run under XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{n} for CPU simulation (see dryrun.py), or pick a smaller "
            f"spec ({_MESH_GRAMMAR})"
        )
    return jax.make_mesh(spec.shape, spec.axes, devices=devices[:n])


# ---------------------------------------------------------------------------
# Back-compat constructors (now routed through MeshSpec/resolve_mesh)
# ---------------------------------------------------------------------------


def make_production_mesh(*, multi_pod: bool = False,
                         shape: Optional[Tuple[int, ...]] = None):
    """Production mesh; ``shape`` overrides the v5e default so CI-sized
    smokes run (e.g. ``shape=(2, 2, 2)`` with ``multi_pod=True`` on 8
    forced host devices).  ``shape`` is (pods, data, model) when
    ``multi_pod`` else (data, model)."""
    if multi_pod:
        spec = MeshSpec.multi_pod(*(shape or (2, 16, 16)))
    else:
        spec = MeshSpec.single_pod(*(shape or (16, 16)))
    return resolve_mesh(spec)


def make_host_mesh():
    """Degenerate 1x1 mesh for CPU smoke runs of the sharded step code."""
    return resolve_mesh(MeshSpec.host())


def make_client_mesh(n_shards: int, axis_name: str = "clients"):
    """1-D mesh over the FL participating-client axis (DESIGN.md §3).

    Used by ``repro.fl.engine`` to split a round's K' clients across local
    devices; the single-axis layout keeps the client phase embarrassingly
    parallel and confines cross-device traffic to the round-boundary
    aggregation collective.
    """
    return resolve_mesh(MeshSpec.clients(n_shards, axis_name))
