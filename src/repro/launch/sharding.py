"""Sharding rules: param / batch / cache pytrees -> PartitionSpec trees.

Megatron-style tensor parallelism on the ``model`` axis, batch parallelism
on ``data``, FL-cohort on ``pod`` (a leading client axis on every state
leaf).  Rules are name-based with divisibility fallbacks: if the preferred
axis of a leaf is not divisible by the model-axis size we fall back to the
next candidate and finally to replication - never GSPMD padding (padding a
4-head gemma3 attention 4x would silently waste 75% of the shard).

Sharded axes by leaf name (unstacked ranks; stacked pattern leaves get a
leading None for the n_rep scan axis):

  embed (V,D)->V | heads (K,D,V)->V | attn wq (D,H,hd)->H else hd
  wk/wv (D,KV,hd)->KV else hd | attn wo (H,hd,D)->H else hd
  mlp wi* (D,F)->F | mlp wo (F,D)->F | moe wi*/wo (E,..)->E (expert par.)
  ssm in_proj (D,Z)->Z | conv (w,C)->C | out_proj (inner,D)->inner
  norms/router/biases -> replicated

KV caches: batch on ``data``, cache sequence dim on ``model`` (decode
attention reduces over the sequence -> XLA inserts the psum; this is what
makes the 1.4 TB gemma2-9b decode_32k cache fit at ~5.5 GB/chip).
SSM decode state: heads on ``model``.
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import PartitionSpec as P


def _path_names(path):
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "idx"):
            out.append(str(k.idx))
        elif hasattr(k, "name"):
            out.append(str(k.name))
    return out


def _div(n, m):
    return m > 0 and n % m == 0


def _param_rule(names, shape, msize):
    """Returns a tuple of axis-name-or-None of len == len(shape)."""
    name = names[-1]
    spec = [None] * len(shape)

    def try_axes(cands):
        for ax in cands:
            if ax < len(shape) and _div(shape[ax], msize):
                spec[ax] = "model"
                return

    if name == "embed":
        try_axes([len(shape) - 2])  # vocab dim ((V,D) or (K,V,D))
    elif name == "heads":
        try_axes([2])  # (K, D, V) -> vocab
    elif name == "wq":
        try_axes([1, 2])  # (D,H,hd)
    elif name in ("wk", "wv"):
        try_axes([1, 2])  # (D,KV,hd)
    elif name == "wo" and len(shape) == 3 and "attn" in names:
        try_axes([0, 1])  # (H,hd,D)
    elif name in ("wi_gate", "wi_up"):
        if len(shape) == 3:  # moe (E,D,F) -> experts
            try_axes([0])
        else:  # mlp (D,F)
            try_axes([1])
    elif name == "wo":
        if len(shape) == 3:  # moe (E,F,D)
            try_axes([0])
        else:  # mlp (F,D)
            try_axes([0])
    elif name == "in_proj":
        try_axes([1])  # (D, Z)
    elif name == "conv_w":
        try_axes([1])  # (w, C)
    elif name == "conv_b":
        try_axes([0])
    elif name == "out_proj":
        try_axes([0])  # (d_inner, D)
    # norms, router, A_log, dt_bias, D, vis_proj, scale -> replicated
    return tuple(spec)


def param_pspecs(params_tree, msize: int, stacked_prefixes=("pattern",),
                 client: bool = False, client_axis: Optional[str] = None):
    """PartitionSpec tree matching ``params_tree`` (arrays or SDS leaves).

    ``client=True``: every leaf carries a leading FL-client axis, sharded
    over ``client_axis`` ("pod" on the multi-pod mesh, None -> replicated
    size-1 axis on the single-pod mesh).  Leaves under ``pattern``
    additionally carry the n_rep scan-stack axis (never sharded).
    """

    def rule(path, leaf):
        names = _path_names(path)
        shape = list(leaf.shape)
        prefix = []
        if client:
            prefix.append(client_axis)
            shape = shape[1:]
        if names and names[0] in stacked_prefixes:
            prefix.append(None)
            shape = shape[1:]
        return P(*prefix, *_param_rule(names, tuple(shape), msize))

    return jax.tree_util.tree_map_with_path(rule, params_tree)


def _cache_rule(names, shape, dsize, msize):
    name = names[-1]
    if name in ("k", "v"):  # (B, cap, KV, hd)
        b, cap = shape[0], shape[1]
        return (
            "data" if _div(b, dsize) else None,
            "model" if _div(cap, msize) else None,
            None,
            None,
        )
    if name in ("k_scale", "v_scale"):  # (B, cap, KV) int8-cache scales
        return (
            "data" if _div(shape[0], dsize) else None,
            "model" if _div(shape[1], msize) else None,
            None,
        )
    if name == "conv":  # (B, w-1, C)
        return (
            "data" if _div(shape[0], dsize) else None,
            None,
            "model" if _div(shape[2], msize) else None,
        )
    if name == "state":  # (B, H, P, N)
        return (
            "data" if _div(shape[0], dsize) else None,
            "model" if _div(shape[1], msize) else None,
            None,
            None,
        )
    return tuple([None] * len(shape))  # pos etc.


def cache_pspecs(cache_tree, dsize: int, msize: int,
                 stacked_prefixes=("pattern",), client: bool = False,
                 client_axis: Optional[str] = None):
    def rule(path, leaf):
        names = _path_names(path)
        shape = list(leaf.shape)
        prefix = []
        if client:
            prefix.append(client_axis)
            shape = shape[1:]
        if names and names[0] in stacked_prefixes:
            prefix.append(None)
            shape = shape[1:]
        return P(*prefix, *_cache_rule(names, tuple(shape), dsize, msize))

    return jax.tree_util.tree_map_with_path(rule, cache_tree)


def batch_pspecs(batch_tree, dsize: int, batch_axis_index: int = 0,
                 client: bool = False, client_axis: Optional[str] = None):
    """Shard the per-step batch dim on ``data`` (replicate if indivisible).

    ``batch_axis_index`` is the position of the batch dim AFTER the client
    axis (train batches are (T, micro_b, ...) -> index 1).
    """

    def rule(path, leaf):
        shape = list(leaf.shape)
        prefix = []
        if client:
            prefix.append(client_axis)
            shape = shape[1:]
        spec = [None] * len(shape)
        if len(shape) > batch_axis_index and _div(shape[batch_axis_index], dsize):
            spec[batch_axis_index] = "data"
        return P(*prefix, *spec)

    return jax.tree_util.tree_map_with_path(rule, batch_tree)


def client_stacked_pspecs(tree, axis_name: Optional[str] = "clients",
                          model_axis: Optional[str] = None, msize: int = 1):
    """Full-rank specs sharding the leading stacked-client axis of every leaf.

    The FL engine stacks per-client state/batch pytrees on a leading K'
    axis (DESIGN.md §3); this returns ``P(axis_name, None, ...)`` per leaf
    for use as shard_map in/out specs — the ``replicated`` rule with the
    client axis sharded.

    ``model_axis``/``msize`` compose the per-leaf ``_param_rule`` on top
    (DESIGN.md §11): each client's slice additionally shards its
    Megatron-eligible dims over the mesh's model axis within a pod —
    ``P(axis_name, ..., model_axis, ...)``.  Leaves whose names match no
    rule (or whose dims are not divisible by ``msize``) stay replicated
    beyond the client axis, so arbitrary method state (the CNN federation)
    composes to exactly the plain client-stacked layout.  The param rules
    emit the literal axis name ``"model"``, so a composing mesh must name
    its model-role axis ``"model"`` (all shipped MeshSpecs do).
    """
    if model_axis is None or msize <= 1:
        return replicated(tree, client=True, client_axis=axis_name)
    if model_axis != "model":
        raise ValueError(
            f"model-axis composition requires the mesh's model-role axis to "
            f"be named 'model' (got {model_axis!r}); the name-based param "
            "rules emit the literal axis name (DESIGN.md §5)"
        )
    return param_pspecs(tree, msize, client=True, client_axis=axis_name)


def replicated(tree, client: bool = False, client_axis: Optional[str] = None):
    def rule(leaf):
        spec = [None] * len(leaf.shape)
        if client and spec:
            spec[0] = client_axis
        return P(*spec)

    return jax.tree.map(rule, tree)
