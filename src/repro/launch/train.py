"""Production training driver.

Assembles mesh + sharding rules + the pFedSOP round step for an assigned
architecture and runs real rounds on whatever devices exist.  On the CPU
container this runs reduced configs on a 1x1 mesh (functional smoke of the
exact production codepath); on a TPU pod slice the same entrypoint builds
the (data, model) mesh and full config.

  PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b --rounds 3 \
      --reduced --seq-len 64 --micro-batch 2 --local-iters 2
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_NAMES, INPUT_SHAPES, get_config
from repro.configs.base import InputShape
from repro.data import lm_batch_iterator, synthetic_lm_stream
from repro.launch import sharding as sh
from repro.launch import steps as st
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import transformer as tf
from repro.obs import ObsConfig, make_obs
from repro.utils.checkpoint import save_checkpoint


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_NAMES), default="granite-3-2b")
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--local-iters", type=int, default=2)
    ap.add_argument("--micro-batch", type=int, default=2)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--production-mesh", action="store_true",
                    help="use the 16x16 mesh (requires 256 devices)")
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--output-sharding", choices=["replicated", "sharded"],
                    default="replicated",
                    help="round-step lowering (DESIGN.md §11): 'sharded' "
                         "routes the client phase + Eq. 13 aggregation "
                         "through the federation MeshBackend engine, so "
                         "client-state outputs stay sharded at rest on a "
                         "client-axis (pods) mesh; 'replicated' keeps the "
                         "plain vmap lowering.  Identical numerics — the "
                         "two share the canonical cohort_mean reduction")
    ap.add_argument("--kernel-impl", default="auto",
                    choices=["auto", "reference", "kernel", "kernel_interpret"],
                    help="model-zoo kernel policy (rmsnorm/flash_gqa, "
                         "DESIGN.md §9); auto = kernel on TPU")
    ap.add_argument("--trace-dir", default="",
                    help="structured round trace + Perfetto trace.json export "
                         "(DESIGN.md §13)")
    ap.add_argument("--metrics", default="",
                    help="metrics.jsonl path ('' = <trace-dir>/metrics.jsonl)")
    ap.add_argument("--obs-level", choices=["off", "round", "phase", "kernel"],
                    default="phase")
    ap.add_argument("--xla-profile", type=int, default=-1,
                    help="round index to wrap in a jax.profiler capture "
                         "under <trace-dir>/xla (-1 = off)")
    ap.add_argument("--obs-quiet", action="store_true",
                    help="suppress stdout progress lines (records still trace)")
    args = ap.parse_args()
    if args.xla_profile >= 0 and not args.trace_dir:
        ap.error("--xla-profile requires --trace-dir")

    cfg = get_config(args.arch, reduced=args.reduced)
    cfg = cfg.replace(kernel_impl=args.kernel_impl)
    if cfg.frontend != "none":
        raise SystemExit("text archs only in this driver")
    mesh = make_production_mesh() if args.production_mesh else make_host_mesh()
    dsize, msize = mesh.shape["data"], mesh.shape["model"]
    obs = make_obs(ObsConfig(
        trace_dir=args.trace_dir, metrics=args.metrics, level=args.obs_level,
        quiet=args.obs_quiet, xla_profile=args.xla_profile,
    ) if (args.trace_dir or args.metrics or args.obs_quiet) else None)
    obs.open(fingerprint={
        "driver": "launch", "arch": cfg.name, "mesh": dict(mesh.shape),
        "seed": args.seed, "kernel_impl": args.kernel_impl,
        "seq_len": args.seq_len, "micro_batch": args.micro_batch,
        "local_iters": args.local_iters,
    })
    obs.log.info(f"mesh {dict(mesh.shape)}, arch {cfg.name}",
                 event="run_start", mesh=dict(mesh.shape), arch=cfg.name)

    shape = InputShape("custom", args.seq_len, args.micro_batch * args.local_iters, "train")
    if args.output_sharding == "sharded":
        from repro.fl.engine import MeshBackend
        from repro.launch.mesh import MeshSpec

        spec = (MeshSpec.single_pod(16, 16) if args.production_mesh
                else MeshSpec.host())
        engine = MeshBackend(1, spec, strict=False, data_chunks=dsize)
        step = st.make_train_step(cfg, shape, engine=engine)
    else:
        step = st.make_train_step(cfg, shape)

    params = tf.init_params(jax.random.PRNGKey(args.seed), cfg)
    zeros = jax.tree.map(jnp.zeros_like, params)
    state = jax.tree.map(lambda x: x[None], {"params": params, "delta": zeros})
    global_delta = zeros

    pspec = sh.param_pspecs(state["params"], msize, client=True)
    in_sh = (
        {"params": pspec, "delta": pspec},
        sh.param_pspecs(global_delta, msize),
        None,
    )
    named = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                   is_leaf=lambda x: isinstance(x, P))
    jit_step = jax.jit(step, in_shardings=(named(in_sh[0]), named(in_sh[1]), None))

    stream = synthetic_lm_stream(50_000, cfg.vocab_size, seed=args.seed)
    it = lm_batch_iterator(stream, args.micro_batch, args.seq_len, seed=args.seed)

    with mesh:
        for r in range(args.rounds):
            t0 = time.perf_counter()
            obs.xla_round_start(r)
            with obs.span("round", round=r):
                bs = [next(it) for _ in range(args.local_iters)]
                batches = jax.tree.map(lambda *xs: jnp.stack(xs)[None], *bs)  # (1,T,b,S)
                state, global_delta, loss = obs.timed(
                    "train_step", jit_step, state, global_delta, batches,
                    round=r)
            obs.xla_round_end(r)
            dt = time.perf_counter() - t0
            obs.log.info(f"round {r} loss={float(loss):.4f} ({dt:.1f}s)",
                         event="round", round=r, loss=float(loss),
                         round_time=dt)
            if obs.metrics is not None:
                obs.metrics.gauge("train.loss").set(float(loss))
                obs.metrics.gauge("train.round_time").set(dt)
                obs.flush_metrics(step=r)
            obs.flush()
            if args.checkpoint_dir:
                save_checkpoint(args.checkpoint_dir, r, state)
    obs.close()
    assert np.isfinite(float(loss))
    print("OK")


if __name__ == "__main__":
    main()
