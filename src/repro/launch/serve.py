"""Production serving driver: batched decode sessions through the sharded
serve_step (the same step dryrun.py lowers at decode_32k / long_500k).

  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --steps 8
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_NAMES, INPUT_SHAPES, get_config
from repro.configs.base import InputShape
from repro.launch import steps as st
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as tf


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_NAMES), default="gemma3-1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--steps", type=int, default=8)
    ap.add_argument("--capacity", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--kernel-impl", default="auto",
                    choices=["auto", "reference", "kernel", "kernel_interpret"],
                    help="model-zoo kernel policy (rmsnorm/flash_gqa, "
                         "DESIGN.md §9); auto = kernel on TPU")
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=True).replace(kernel_impl=args.kernel_impl)
    mesh = make_host_mesh()
    shape = InputShape("custom_decode", args.capacity, args.batch, "decode")
    serve_step = jax.jit(st.make_serve_step(cfg, shape))

    params = tf.init_params(jax.random.PRNGKey(args.seed), cfg)
    params1 = jax.tree.map(lambda x: x[None], params)
    caches = jax.tree.map(lambda x: x[None], tf.init_caches(cfg, args.batch, args.capacity))

    if cfg.frontend == "audio_codebooks":
        tok = jnp.zeros((1, args.batch, cfg.n_codebooks, 1), jnp.int32)
    else:
        tok = jnp.zeros((1, args.batch, 1), jnp.int32)
    batch = {"tokens": tok}
    if cfg.frontend == "vision_stub":
        batch["patch_embeds"] = jnp.zeros((1, args.batch, 0, cfg.d_vision), jnp.float32)

    with mesh:
        t0 = time.perf_counter()
        for t in range(args.steps):
            token, caches = serve_step(params1, batch, jnp.asarray(t, jnp.int32), caches)
            nxt = token.reshape(1, args.batch, -1)[..., :1]
            if cfg.frontend == "audio_codebooks":
                nxt = jnp.broadcast_to(token.reshape(1, args.batch, cfg.n_codebooks)[..., None],
                                       (1, args.batch, cfg.n_codebooks, 1))
            batch["tokens"] = nxt.astype(jnp.int32)
        dt = time.perf_counter() - t0
    print(f"{args.steps} decode steps x {args.batch} seqs in {dt:.2f}s; "
          f"last tokens {np.asarray(token).ravel()[:8]}")
    assert np.all(np.asarray(token) >= 0)
    print("OK")


if __name__ == "__main__":
    main()
