"""Step definitions lowered by the dry-run and executed by the drivers.

The pFedSOP production round-step (the paper's Algorithm 3, one client
cohort per pod) is:

  per client (vmapped over the leading client axis; multi-pod shards it
  over ``pod``):
    1. personalize: Gompertz-weighted aggregation of (local delta, global
       delta) + Sherman-Morrison FIM step    (Algorithm 1 - the paper)
    2. T local SGD iterations over the round's microbatches (Algorithm 2);
       one scan step per microbatch, so activation memory is bounded by a
       single microbatch while the FLOPs match the full global batch
    3. new local delta = (x0 - xT)/eta2
  server:
    4. global delta = mean over the client axis (Eq. 13) - this mean IS
       the cross-pod all-reduce in the lowered HLO.

Serving:
  prefill_step  full forward, last-position logits (cache write-out is
                elided in the dry-run; DESIGN.md §8)
  serve_step    one new token against a KV cache of seq_len (decode
                shapes); greedy sampling.

``input_specs(cfg, shape)`` builds ShapeDtypeStruct stand-ins for every
input - weak-type-correct, shardable, no device allocation.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import InputShape, ModelConfig
from repro.core import pfedsop as pf
from repro.models import transformer as tf
from repro.models.transformer import apply_long_context
from repro.optim.reduce import cohort_mean
from repro.optim.sgd import chunked_value_and_grad

MICRO_BATCH = 32  # per-SGD-iteration batch for train_4k (T = 256/32 = 8)


def resolve_cfg(cfg: ModelConfig, shape: InputShape) -> ModelConfig:
    if shape.name == "long_500k":
        return apply_long_context(cfg)
    return cfg


# ---------------------------------------------------------------------------
# ShapeDtypeStruct input builders
# ---------------------------------------------------------------------------


def _token_batch(cfg, b, s):
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct
    if cfg.frontend == "audio_codebooks":
        return {"tokens": sds((b, cfg.n_codebooks, s), i32),
                "labels": sds((b, cfg.n_codebooks, s), i32)}
    if cfg.frontend == "vision_stub":
        s_text = s - cfg.n_patches
        return {
            "tokens": sds((b, s_text), i32),
            "labels": sds((b, s_text), i32),
            "patch_embeds": sds((b, cfg.n_patches, cfg.d_vision), jnp.float32),
        }
    return {"tokens": sds((b, s), i32), "labels": sds((b, s), i32)}


def _decode_batch(cfg, b):
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct
    if cfg.frontend == "audio_codebooks":
        return {"tokens": sds((b, cfg.n_codebooks, 1), i32)}
    if cfg.frontend == "vision_stub":
        return {"tokens": sds((b, 1), i32),
                "patch_embeds": sds((b, 0, cfg.d_vision), jnp.float32)}
    return {"tokens": sds((b, 1), i32)}


def abstract_params(cfg) -> Any:
    return jax.eval_shape(lambda k: tf.init_params(k, cfg), jax.random.PRNGKey(0))


def abstract_caches(cfg, batch, seq_len) -> Any:
    return jax.eval_shape(lambda: tf.init_caches(cfg, batch, seq_len))


def _stack_client(tree, n_clients):
    return jax.tree.map(
        lambda l: jax.ShapeDtypeStruct((n_clients,) + tuple(l.shape), l.dtype), tree
    )


def input_specs(cfg: ModelConfig, shape: InputShape, n_clients: int = 1,
                micro_batch: int = MICRO_BATCH,
                t_override: Optional[int] = None) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for the step inputs of (arch x shape).

    ``t_override`` pins the local-SGD iteration count (the roofline
    calibration lowers T=1 so every loop has a single trip).
    """
    cfg = resolve_cfg(cfg, shape)
    params = abstract_params(cfg)

    if shape.kind == "train":
        mb = min(micro_batch, shape.global_batch)
        t = t_override or max(1, shape.global_batch // mb)
        batches = jax.tree.map(
            lambda l: jax.ShapeDtypeStruct((t,) + tuple(l.shape), l.dtype),
            _token_batch(cfg, mb, shape.seq_len),
        )
        state = {"params": params, "delta": params}
        return {
            "state": _stack_client(state, n_clients),
            "global_delta": params,  # replicated broadcast from the server
            "batches": _stack_client(batches, n_clients),
        }

    if shape.kind == "prefill":
        return {
            "params": _stack_client(params, n_clients),
            "batch": _stack_client(_token_batch(cfg, shape.global_batch, shape.seq_len), n_clients),
        }

    # decode
    caches = abstract_caches(cfg, shape.global_batch, shape.seq_len)
    return {
        "params": _stack_client(params, n_clients),
        "batch": _stack_client(_decode_batch(cfg, shape.global_batch), n_clients),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
        "caches": _stack_client(caches, n_clients),
    }


# ---------------------------------------------------------------------------
# Steps
# ---------------------------------------------------------------------------


def make_train_step(cfg: ModelConfig, shape: InputShape,
                    pcfg: Optional[pf.PFedSOPConfig] = None,
                    use_pfedsop: bool = True, engine=None):
    """Returns train_step(state, global_delta, batches) -> (state', gd', loss).

    state/batches carry a leading client axis (size = #pods, 1 on the
    single-pod mesh).  ``use_pfedsop=False`` gives the plain-FedAvg round
    (the paper-baseline lowering used for the roofline delta of the
    technique itself).

    ``engine`` is an optional ``repro.fl.engine.MeshBackend``: the lowering
    then routes the per-client phase through ``client_phase_sharded`` and
    Eq. 13 through ``aggregate_phase`` — the exact mesh code path the
    federation drivers run (DESIGN.md §11) — instead of a hand-rolled
    vmap + mean.  Both paths reduce with the canonical halving-tree
    ``cohort_mean``, so the two lowerings agree bitwise on a shared mesh.
    """
    cfg = resolve_cfg(cfg, shape)
    pcfg = pcfg or pf.PFedSOPConfig()

    def loss_fn(p, batch):
        return tf.lm_loss(p, cfg, batch)

    # chunk-tree gradient: identical to jax.value_and_grad outside any
    # grad-chunk/data-shard context, and the data-axis local SGD when the
    # engine shards the per-client batch over the mesh's data axis (§11)
    grad_fn = chunked_value_and_grad(loss_fn)

    def client_step(state, global_delta, batches):
        params = state["params"]
        if use_pfedsop:
            params, _ = pf.personalize(params, state["delta"], global_delta, pcfg)

        def sgd_iter(p, batch):
            loss, g = grad_fn(p, batch)
            p = jax.tree.map(
                lambda x, gi: (x.astype(jnp.float32) - pcfg.eta2 * gi.astype(jnp.float32)).astype(x.dtype),
                p, g,
            )
            return p, loss

        final, losses = jax.lax.scan(sgd_iter, params, batches)
        delta = jax.tree.map(
            lambda a, b: ((a.astype(jnp.float32) - b.astype(jnp.float32)) / pcfg.eta2).astype(a.dtype),
            params, final,
        )
        return {"params": final, "delta": delta}, jnp.mean(losses)

    def server(global_delta_, deltas, losses):
        # Eq. 13 server aggregation — the canonical cohort mean, which IS
        # the cross-pod all-reduce when traced inside ``aggregate_phase``
        del global_delta_
        new_global = jax.tree.map(
            lambda d, m: m.astype(d.dtype), deltas, cohort_mean(deltas))
        return new_global, cohort_mean(losses)

    def train_step(state, global_delta, batches):
        new_state, losses = jax.vmap(client_step, in_axes=(0, None, 0))(
            state, global_delta, batches
        )
        new_global, loss = server(global_delta, new_state["delta"], losses)
        return new_state, new_global, loss

    if engine is None:
        return train_step

    def train_step_engine(state, global_delta, batches):
        new_state, losses = engine.client_phase_sharded(
            client_step, state, global_delta, batches)
        if engine.client_sharded:
            new_global, loss = engine.aggregate_phase(
                server, global_delta, new_state["delta"], losses)
        else:  # no client-role axis (single pod): outputs already replicated
            new_global, loss = server(global_delta, new_state["delta"], losses)
        return new_state, new_global, loss

    return train_step_engine


def make_prefill_step(cfg: ModelConfig, shape: InputShape):
    cfg = resolve_cfg(cfg, shape)

    def prefill_one(params, batch):
        hidden, _ = tf.forward(params, cfg, batch)
        logits = tf.lm_logits(params, cfg, hidden[:, -1:, :])
        return logits

    def prefill_step(params, batch):
        return jax.vmap(prefill_one)(params, batch)

    return prefill_step


def make_serve_step(cfg: ModelConfig, shape: InputShape):
    cfg = resolve_cfg(cfg, shape)

    def decode_one(params, batch, pos, caches):
        logits, new_caches = tf.decode_step(params, cfg, batch, pos, caches)
        token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return token, new_caches

    def serve_step(params, batch, pos, caches):
        return jax.vmap(decode_one, in_axes=(0, 0, None, 0))(params, batch, pos, caches)

    return serve_step
