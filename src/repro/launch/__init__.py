"""Launch layer: production mesh, sharding rules, step definitions,
multi-pod dry-run, roofline analysis, and the train/serve drivers."""
