import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Calibrated roofline: exact per-layer unit costs from the compiled
artifact, composed analytically over the loop trip counts.

WHY: XLA's ``cost_analysis()`` counts a ``while``-loop body ONCE regardless
of trip count (verified empirically - scan(n=1) and scan(n=16) report the
same FLOPs).  The production lowering uses scan over (a) the pattern
repetitions, (b) the T local-SGD iterations, (c) attention q-blocks and
(d) SSD chunks, so its raw cost numbers under-report looped work by up to
~TxN_rep (e.g. 384x for musicgen train_4k).

METHOD (two-point unit calibration):
  lower variant A: pattern unrolled ONCE  (tail=pattern, no layer scan),
                   T=1 (length-1 SGD scan), attn_q_block=seq,
                   ssm_chunk=seq  -> every loop has trip count 1, so
                   cost_analysis is exact for this shallow model;
  lower variant B: pattern unrolled TWICE -> per-pattern unit cost =
                   cost(B) - cost(A), exactly (the only difference is one
                   more pattern's worth of compute/bytes/collectives);
  compose:  total = T x [ (A - unit) + unit x n_rep + unit/|pattern| x |tail| ]
  (T multiplies everything because embed/head/grad all sit inside the
  per-iteration body; the once-per-step pFedSOP scalar work is O(3d) and
  negligible - documented overcount.)

The same A/B differencing corrects the collective-byte census.  The HBM
footprint (memory_analysis) is NOT corrected - the production scan
lowering's footprint is the real deployment footprint and is reported
from the baseline artifact.

  PYTHONPATH=src python -m repro.launch.calibrate --all
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax

from repro.configs import ARCH_NAMES, INPUT_SHAPES, get_config
from repro.launch import steps as st
from repro.launch.roofline import (
    HBM_BW, ICI_BW, PEAK_FLOPS, collective_bytes_from_hlo,
)

ART_DIR = Path(__file__).resolve().parents[3] / "experiments" / "roofline"


def _unrolled_cfg(cfg, shape, n_copies: int, ssm_chunk=None):
    seq = shape.seq_len
    pattern = tuple(cfg.pattern) * n_copies
    chunk = ssm_chunk or seq  # default: single SSD chunk (trip count 1)
    return cfg.replace(
        pattern=(), n_rep=0, tail=pattern,
        n_layers=len(pattern),
        ssm_chunk=chunk,
        # if chunked, unroll the inter-chunk scan so every trip is counted
        ssm_scan_unroll=max(1, seq // chunk),
        attn_q_block=seq,
    )


def _measure(arch, shape_name, n_copies, variant, micro_batch, ssm_chunk=None):
    """Lower one unrolled variant on the single-pod mesh; exact costs."""
    from repro.launch.dryrun import build_lowering  # shares the step builders
    import repro.launch.dryrun as dr

    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]

    # monkey-patch the config the builder sees (keeps one code path);
    # variant flags (moe_dispatch / seqshard) are applied by build_lowering
    ucfg = _unrolled_cfg(cfg, shape, n_copies, ssm_chunk=ssm_chunk)
    orig_get = dr.get_config
    dr.get_config = lambda name: ucfg
    try:
        lowered, meta, mesh = dr.build_lowering(
            arch, shape_name, multi_pod=False, micro_batch=micro_batch,
            variant=variant, t_override=1,
        )
    finally:
        dr.get_config = orig_get
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    coll = collective_bytes_from_hlo(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "collective_bytes": sum(v["bytes"] for v in coll.values()),
        "collectives": coll,
    }


def calibrate_one(arch, shape_name, variant="baseline",
                  micro_batch=st.MICRO_BATCH, save=True, verbose=True,
                  ssm_chunk=None, tag_suffix=""):
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    rcfg = st.resolve_cfg(cfg, shape)
    t0 = time.time()
    a = _measure(arch, shape_name, 1, variant, micro_batch, ssm_chunk=ssm_chunk)
    b = _measure(arch, shape_name, 2, variant, micro_batch, ssm_chunk=ssm_chunk)
    t_cal = time.time() - t0

    n_pat = len(rcfg.pattern)
    reps = rcfg.n_rep
    tail_frac = len(rcfg.tail) / max(1, n_pat)
    if shape.kind == "train":
        mb = min(micro_batch, shape.global_batch)
        t_iters = max(1, shape.global_batch // mb)
    else:
        t_iters = 1

    def compose(key):
        unit = b[key] - a[key]
        fixed = a[key] - unit
        return t_iters * (fixed + unit * (reps + tail_frac))

    flops_dev = compose("flops")
    bytes_dev = compose("bytes")
    coll_dev = compose("collective_bytes")

    n_dev = 256
    record = {
        "arch": arch, "shape": shape_name, "mesh": "16x16", "variant": variant,
        "method": "two-point unit calibration (see launch/calibrate.py)",
        "t_iters": t_iters, "n_rep": reps, "pattern_len": n_pat,
        "unit_flops_per_pattern": b["flops"] - a["flops"],
        "fixed_flops": 2 * a["flops"] - b["flops"],
        "per_device": {"flops": flops_dev, "bytes": bytes_dev,
                       "collective_bytes": coll_dev},
        "roofline": {
            "compute_s": flops_dev / PEAK_FLOPS,
            "memory_s": bytes_dev / HBM_BW,
            "collective_s": coll_dev / ICI_BW,
        },
        "total_flops": flops_dev * n_dev,
        "total_bytes": bytes_dev * n_dev,
        "calibrate_s": round(t_cal, 1),
    }
    terms = record["roofline"]
    record["roofline"]["dominant"] = max(
        ("compute_s", "memory_s", "collective_s"), key=lambda k: terms[k]
    ).replace("_s", "")

    if verbose:
        print(f"== {arch} x {shape_name} ({variant}) calibrated in {t_cal:.0f}s ==")
        print(f"   roofline: " + " ".join(
            f"{k}={v:.4g}" if isinstance(v, float) else f"{k}={v}"
            for k, v in record["roofline"].items()))
    if save:
        ART_DIR.mkdir(parents=True, exist_ok=True)
        tag = f"{arch}__{shape_name}__16x16"
        if variant != "baseline":
            tag += f"__{variant}"
        if tag_suffix:
            tag += f"__{tag_suffix}"
        (ART_DIR / f"{tag}.json").write_text(json.dumps(record, indent=1))
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_NAMES), default=None)
    ap.add_argument("--shape", choices=list(INPUT_SHAPES), default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--ssm-chunk", type=int, default=None)
    ap.add_argument("--tag-suffix", default="")
    args = ap.parse_args()

    archs = list(ARCH_NAMES) if (args.all or not args.arch) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or not args.shape) else [args.shape]
    failures = []
    for arch in archs:
        for shape in shapes:
            try:
                calibrate_one(arch, shape, variant=args.variant,
                              ssm_chunk=args.ssm_chunk,
                              tag_suffix=args.tag_suffix)
            except Exception as e:  # noqa: BLE001
                failures.append((arch, shape, repr(e)))
                print(f"!! FAIL {arch} x {shape}: {e}")
                traceback.print_exc()
    if failures:
        print(f"{len(failures)} failures: {failures}")
        raise SystemExit(1)
    print("CALIBRATION COMPLETE")


if __name__ == "__main__":
    main()
