import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) combination against the production mesh, with NO device allocation
(ShapeDtypeStruct stand-ins).

    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-1b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all            # 40 pairs, both meshes
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh single

Per combination it records to experiments/dryrun/<arch>__<shape>__<mesh>.json:
  - compiled.memory_analysis()  (bytes/device: proves the config fits HBM)
  - compiled.cost_analysis()    (HLO FLOPs / bytes for §Roofline)
  - the collective-op byte census parsed from the post-SPMD HLO text
  - input/output sharding specs (audit trail)

The XLA_FLAGS line above MUST run before any jax import: jax locks the
device count at first initialisation.  Do not set it globally - smoke
tests and benches see the real single CPU device.
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_NAMES, INPUT_SHAPES, get_config
from repro.fl.engine import MeshBackend
from repro.launch import sharding as sh
from repro.launch import steps as st
from repro.launch.mesh import MeshSpec
from repro.launch.roofline import collective_bytes_from_hlo, roofline_terms

ART_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def _named(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def _strip_model_axis(spec_tree):
    """seqshard variant: layer weights replicated (sequence parallelism
    shards the residual stream instead); embedding keeps its vocab shard."""

    def strip(path, s):
        names = [str(getattr(k, "key", "")) for k in path]
        if "embed" in names or "heads" in names:
            return s
        return P(*[None if ax == "model" else ax for ax in s])

    return jax.tree_util.tree_map_with_path(
        strip, spec_tree, is_leaf=lambda x: isinstance(x, P)
    )


def build_lowering(arch: str, shape_name: str, multi_pod: bool,
                   micro_batch: int = st.MICRO_BATCH, variant: str = "baseline",
                   t_override=None):
    """Lower one (arch, shape, mesh) combination; returns (lowered, meta)."""
    cfg = get_config(arch)
    if variant == "moe_dispatch":
        cfg = cfg.replace(moe_impl="dispatch")
    elif variant == "moe_grouped":
        cfg = cfg.replace(moe_impl="dispatch_grouped")
    elif variant == "seqshard":
        cfg = cfg.replace(seq_shard=True)
    shape = INPUT_SHAPES[shape_name]
    # one mesh code path with the federation engine (DESIGN.md §11): the
    # production MeshSpec resolves through MeshBackend, and the train
    # lowering below routes its client phase + Eq. 13 aggregation through
    # the same engine the FL drivers run
    spec = (MeshSpec.multi_pod(2, 16, 16) if multi_pod
            else MeshSpec.single_pod(16, 16))
    n_clients = spec.client_size if multi_pod else 1
    engine = MeshBackend(n_clients, spec, strict=False,
                         data_chunks=spec.data_size)
    mesh = engine.mesh
    dsize = mesh.shape["data"]
    msize = mesh.shape["model"]
    client_axis = "pod" if multi_pod else None

    if micro_batch == st.MICRO_BATCH:  # CLI default -> per-arch override
        micro_batch = min(micro_batch, cfg.train_micro_batch)
    specs = st.input_specs(cfg, shape, n_clients=n_clients,
                           micro_batch=micro_batch, t_override=t_override)
    rcfg = st.resolve_cfg(cfg, shape)

    donate = ()
    if shape.kind == "train":
        step = st.make_train_step(cfg, shape, engine=engine)
        donate = (0,)  # client state updated in place (params + delta)
        pp = lambda t: sh.param_pspecs(t, msize, client=True, client_axis=client_axis)
        gp = lambda t: sh.param_pspecs(t, msize)
        if variant == "seqshard":
            _pp, _gp = pp, gp
            pp = lambda t: _strip_model_axis(_pp(t))
            gp = lambda t: _strip_model_axis(_gp(t))
        in_shardings = (
            {
                "params": pp(specs["state"]["params"]),
                "delta": pp(specs["state"]["delta"]),
            },
            gp(specs["global_delta"]),
            sh.batch_pspecs(specs["batches"], dsize, batch_axis_index=1,
                            client=True, client_axis=client_axis),
        )
        out_shardings = (in_shardings[0], in_shardings[1], P())
        args = (specs["state"], specs["global_delta"], specs["batches"])
    elif shape.kind == "prefill":
        step = st.make_prefill_step(cfg, shape)
        ppre = sh.param_pspecs(specs["params"], msize, client=True, client_axis=client_axis)
        if variant == "seqshard":
            ppre = _strip_model_axis(ppre)
        in_shardings = (
            ppre,
            sh.batch_pspecs(specs["batch"], dsize, batch_axis_index=0,
                            client=True, client_axis=client_axis),
        )
        out_shardings = P(client_axis)  # last-token logits
        args = (specs["params"], specs["batch"])
    else:  # decode
        step = st.make_serve_step(cfg, shape)
        donate = (3,)  # KV caches / SSM state updated in place
        cache_sh = sh.cache_pspecs(specs["caches"], dsize, msize,
                                   client=True, client_axis=client_axis)
        in_shardings = (
            sh.param_pspecs(specs["params"], msize, client=True, client_axis=client_axis),
            sh.batch_pspecs(specs["batch"], dsize, batch_axis_index=0,
                            client=True, client_axis=client_axis),
            P(),
            cache_sh,
        )
        out_shardings = (P(client_axis), cache_sh)
        args = (specs["params"], specs["batch"], specs["pos"], specs["caches"])

    jitted = jax.jit(
        step,
        in_shardings=_named(mesh, in_shardings),
        out_shardings=_named(mesh, out_shardings),
        # donation = in-place state/cache update on the pod (the deployment
        # semantics; also removes the output-buffer double count from
        # memory_analysis - §Perf iteration 2)
        donate_argnums=donate,
    )
    with mesh:
        lowered = jitted.lower(*args)
    meta = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "variant": variant,
        "n_devices": int(len(mesh.devices.flat)),
        "kind": shape.kind,
        "micro_batch": micro_batch if shape.kind == "train" else None,
        "long_context_mode": rcfg.long_context_mode if shape_name == "long_500k" else None,
        "cfg_name": rcfg.name,
    }
    return lowered, meta, mesh


def run_one(arch: str, shape_name: str, multi_pod: bool, save: bool = True,
            verbose: bool = True, variant: str = "baseline",
            micro_batch: int = st.MICRO_BATCH):
    t0 = time.time()
    lowered, meta, mesh = build_lowering(arch, shape_name, multi_pod,
                                         micro_batch=micro_batch, variant=variant)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # jax < 0.5 returns [per-module dict]
        cost = cost[0] if cost else {}
    coll = collective_bytes_from_hlo(compiled.as_text())

    record = dict(meta)
    record["lower_s"] = round(t_lower, 2)
    record["compile_s"] = round(t_compile, 2)
    record["memory_analysis"] = {
        k: int(getattr(mem, k))
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes")
        if hasattr(mem, k)
    }
    record["cost_analysis"] = {
        k: float(v) for k, v in (cost or {}).items()
        if isinstance(v, (int, float)) and (k in ("flops", "bytes accessed") or k.startswith("bytes accessed"))
    }
    record["collectives"] = coll
    record["roofline"] = roofline_terms(record, n_devices=meta["n_devices"])

    if verbose:
        print(f"== {arch} x {shape_name} [{record['mesh']}] ({variant}) ==")
        print(f"   lower {t_lower:.1f}s compile {t_compile:.1f}s")
        print(f"   memory_analysis: {record['memory_analysis']}")
        print(f"   cost: flops={record['cost_analysis'].get('flops')} "
              f"bytes={record['cost_analysis'].get('bytes accessed')}")
        print(f"   collectives: " + ", ".join(
            f"{k}={v['bytes']:.3e}B x{v['count']}" for k, v in coll.items()) or "none")
        print(f"   roofline: {record['roofline']}")

    if save:
        ART_DIR.mkdir(parents=True, exist_ok=True)
        tag = f"{arch}__{shape_name}__{record['mesh']}"
        if variant != "baseline":
            tag += f"__{variant}"
        (ART_DIR / f"{tag}.json").write_text(json.dumps(record, indent=1))
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_NAMES), default=None)
    ap.add_argument("--shape", choices=list(INPUT_SHAPES), default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--micro-batch", type=int, default=st.MICRO_BATCH)
    args = ap.parse_args()

    archs = list(ARCH_NAMES) if (args.all or args.arch is None) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    run_one(arch, shape, mp, variant=args.variant,
                            micro_batch=args.micro_batch)
                except Exception as e:  # noqa: BLE001 - report, keep sweeping
                    failures.append((arch, shape, mp, repr(e)))
                    print(f"!! FAIL {arch} x {shape} multi_pod={mp}: {e}")
                    traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print("\nALL DRY-RUNS PASSED")


if __name__ == "__main__":
    main()
