"""Roofline analysis from the compiled dry-run artifact.

Three terms, per (arch x shape x mesh), TPU v5e constants:

  compute    = HLO_FLOPs            / (chips x 197e12 FLOP/s bf16)
  memory     = HLO_bytes_accessed   / (chips x 819e9  B/s HBM)
  collective = collective_bytes     / (chips x 50e9   B/s per ICI link)

``cost_analysis()`` on a partitioned executable reports the PER-DEVICE
module cost, so chips divides out of the first two terms - we multiply
back to totals for reporting and divide again for seconds (documented in
EXPERIMENTS.md §Roofline).  Collective bytes are NOT in cost_analysis:
``collective_bytes_from_hlo`` parses the post-SPMD optimized HLO text and
sums operand sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute op (per-device traffic).
"""
from __future__ import annotations

import re
from typing import Dict

PEAK_FLOPS = 197e12  # bf16 FLOP/s per v5e chip
HBM_BW = 819e9  # B/s per chip
ICI_BW = 50e9  # B/s per link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  %all-reduce.5 = bf16[4,128]{1,0} all-reduce(...)
#       ROOT %r = (f32[2]{0}, f32[]) all-to-all(...)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Census of collective ops in post-SPMD HLO: {op: {bytes, count}}.

    Bytes = the op's RESULT shape(s) (per-device).  ``-start`` variants are
    counted, ``-done`` skipped (same payload, avoids double count).
    """
    out: Dict[str, Dict[str, float]] = {}
    for line in hlo_text.splitlines():
        line = line.strip()
        eq = line.find(" = ")
        if eq < 0:
            continue
        rhs = line[eq + 3:]
        for op in _COLLECTIVES:
            # match "op(" or "op-start(" at the op-name position
            m = re.search(rf"\b{op}(?:-start)?\(", rhs)
            if not m:
                continue
            if f"{op}-done" in rhs:
                continue
            nbytes = _shape_bytes(rhs[: m.start()])
            d = out.setdefault(op, {"bytes": 0.0, "count": 0})
            d["bytes"] += nbytes
            d["count"] += 1
            break
    return out


def roofline_terms(record: dict, n_devices: int) -> dict:
    """Seconds per term + dominant bottleneck, from a dry-run record."""
    cost = record.get("cost_analysis", {})
    flops_dev = float(cost.get("flops", 0.0))  # per-device (post-SPMD module)
    bytes_dev = float(cost.get("bytes accessed", 0.0))
    coll_dev = sum(v["bytes"] for v in record.get("collectives", {}).values())

    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_dev / ICI_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory, "collective_s": t_coll}
    dom = max(terms, key=terms.get)
    return {
        **{k: float(f"{v:.6g}") for k, v in terms.items()},
        "dominant": dom.replace("_s", ""),
        "total_flops": flops_dev * n_devices,
        "total_bytes": bytes_dev * n_devices,
        "collective_bytes_per_device": coll_dev,
    }


def model_flops(cfg, shape, n_tokens: int = None) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE) for the step's tokens.

    N counted from the config analytically (embedding excluded, matching
    the convention); D = tokens processed by the step.
    """
    n_active = active_param_count(cfg)
    if n_tokens is None:
        if shape.kind == "train":
            n_tokens = shape.global_batch * shape.seq_len
        elif shape.kind == "prefill":
            n_tokens = shape.global_batch * shape.seq_len
        else:
            n_tokens = shape.global_batch  # one new token per sequence
    mult = 6 if shape.kind == "train" else 2  # fwd+bwd vs fwd
    return float(mult * n_active * n_tokens)


def active_param_count(cfg) -> float:
    """Analytic non-embedding active-parameter count for the config."""
    d, hd = cfg.d_model, cfg.head_dim
    h, kv = cfg.n_heads, cfg.n_kv_heads
    per_layer = {}
    attn = d * h * hd + 2 * d * kv * hd + h * hd * d
    mlp = 3 * d * cfg.d_ff if cfg.d_ff else 0
    moe_active = 3 * d * cfg.expert_ff * cfg.top_k + d * cfg.n_experts if cfg.n_experts else 0
    if cfg.ssm_state:
        d_inner = cfg.ssm_expand * d
        nh = d_inner // cfg.ssm_head_dim
        z = 2 * d_inner + 2 * cfg.ssm_state + nh
        ssm = d * z + d_inner * d
    else:
        ssm = 0
    total = 0.0
    for spec in cfg.layers:
        if spec.kind == "ssm":
            total += ssm
        elif spec.kind == "moe":
            total += attn + moe_active
        else:
            total += attn + mlp
    return total
