"""End-to-end driver: the paper's experiment, miniaturised for CPU.

Heterogeneously-partitioned synthetic image classification across K
clients with partial participation, comparing pFedSOP against the
baselines (FedAvg / FedProx / FT variants / Ditto / FedRep / local-only)
under identical initialization - the setup of pFedSOP Sec. V.

Examples:
  PYTHONPATH=src python examples/train_federated.py                     # default small run
  PYTHONPATH=src python examples/train_federated.py --methods pfedsop fedavg \
      --rounds 30 --clients 20 --partition pathological
  PYTHONPATH=src python examples/train_federated.py --paper-scale       # K=100, 20%%, T=100

  # asynchronous federation (DESIGN.md §10): heterogeneous client speeds,
  # 30%% availability, FedBuff-style buffered staleness-weighted updates
  PYTHONPATH=src python examples/train_federated.py --mode async \
      --speed lognormal --availability 0.3 --buffer-size 4

  # replay a recorded device trace instead of the generative model
  PYTHONPATH=src python examples/train_federated.py --mode async \
      --availability trace:examples/traces/device_trace_8.json

  # multi-pod mesh engine (DESIGN.md §11): cohort over 2 pods, model=2
  # tensor shards per pod (8 devices; forced host devices on CPU)
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python examples/train_federated.py \
      --backend mesh --mesh pods:2x2x2

  # fleet scale (DESIGN.md §12): client state at rest on host (or disk
  # with --store mmap), gathered to device per round; LRU-cache the 50
  # hottest clients' device rows
  PYTHONPATH=src python examples/train_federated.py --clients 2000 \
      --participation 0.01 --store host --cache-clients 50

  # checkpoint every 5 server updates and resume an interrupted run
  PYTHONPATH=src python examples/train_federated.py --mode async \
      --ckpt-every 5 --ckpt-dir experiments/ckpt/demo
  PYTHONPATH=src python examples/train_federated.py --mode async \
      --ckpt-every 5 --ckpt-dir experiments/ckpt/demo --resume

Writes per-method histories to experiments/fl/<tag>.json (consumed by
benchmarks/run.py for the Table II/III/IV analogs).
"""
import argparse
import json
from dataclasses import replace
from pathlib import Path

import jax
import numpy as np

from repro.configs.resnet_cifar import RESNET9_CIFAR100, SMALL_CNN
from repro.core.baselines import METHODS, FedRep
from repro.core.pfedsop import PFedSOPConfig
from repro.core import baselines as bl
from repro.data import (
    FederatedData,
    dirichlet_partition,
    make_class_conditional_images,
    pathological_partition,
)
from repro.fl import (
    AsyncConfig,
    AsyncFederation,
    AvailabilityConfig,
    Federation,
    FLRunConfig,
    StoreConfig,
    TraceAvailabilityConfig,
    make_availability,
)
from repro.fl.runtime import masked_accuracy
from repro.models import cnn
from repro.obs import ObsConfig
from repro.utils.checkpoint import latest_step, save_checkpoint


def build_method(name, lr, args):
    if name == "pfedsop":
        return bl.PFedSOP(cfg=PFedSOPConfig(eta1=lr, eta2=lr, rho=args.rho, lam=args.lam))
    if name == "pfedsop_nopc":
        m = bl.PFedSOP(cfg=PFedSOPConfig(eta1=lr, eta2=lr, rho=args.rho,
                                         lam=args.lam, use_pc=False))
        return type(m)(cfg=m.cfg, name="pfedsop_nopc")
    if name == "fedrep":
        return FedRep(lr=lr, head_predicate=lambda p: "fc_" in p)
    if name == "fedprox":
        return bl.FedProx(lr=lr, mu=args.mu)
    if name == "fedprox_ft":
        return bl.FedProxFT(lr=lr, mu=args.mu)
    if name == "ditto":
        return bl.Ditto(lr=lr, lam=args.ditto_lam)
    return METHODS[name](lr=lr)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--methods", nargs="+", default=["pfedsop", "fedavg"],
                    choices=sorted(METHODS) + ["pfedsop_nopc"])
    ap.add_argument("--partition", choices=["dirichlet", "pathological"],
                    default="dirichlet")
    ap.add_argument("--alpha", type=float, default=0.07)  # paper Dir(0.07)
    ap.add_argument("--shard-size", type=int, default=100)
    ap.add_argument("--clients", type=int, default=20)
    ap.add_argument("--participation", type=float, default=0.2)
    ap.add_argument("--rounds", type=int, default=15)
    ap.add_argument("--samples", type=int, default=4000)
    ap.add_argument("--classes", type=int, default=10)
    ap.add_argument("--image-size", type=int, default=16)
    ap.add_argument("--batch", type=int, default=50)  # paper batch size
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--rho", type=float, default=1.0)
    ap.add_argument("--lam", type=float, default=1.0)
    ap.add_argument("--mu", type=float, default=0.1)
    ap.add_argument("--ditto-lam", type=float, default=0.1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--backend", choices=["vmap", "shard_map", "mesh"],
                    default="vmap",
                    help="federation engine backend (DESIGN.md §3/§11); "
                         "shard_map splits the participating clients across "
                         "local devices on a 1-D mesh; mesh runs the "
                         "role-named mesh engine selected by --mesh")
    ap.add_argument("--shards", type=int, default=0,
                    help="shard_map only: device-shard count (0 = auto)")
    ap.add_argument("--mesh", default="",
                    help="mesh backend only: mesh spec (repro.launch.mesh."
                         "parse_mesh) — 'clients[:N]' | 'host' | 'pod:DxM' | "
                         "'pods:PxDxM'; e.g. 'pods:2x2x2' shards the cohort "
                         "over 2 pods with model=2 tensor shards each "
                         "(8 devices; run under XLA_FLAGS="
                         "--xla_force_host_platform_device_count=8 on CPU)")
    ap.add_argument("--output-sharding", choices=["replicated", "sharded"],
                    default="replicated",
                    help="round-boundary output layout (DESIGN.md §11): "
                         "'replicated' all-gathers engine outputs at the "
                         "round boundary (the seed contract); 'sharded' "
                         "keeps them client-sharded at rest and lowers "
                         "Eq. 13 aggregation into the sharded program — "
                         "bitwise-identical histories, no all-gather span. "
                         "shard_map/mesh backends only")
    ap.add_argument("--grad-chunks", type=int, default=1,
                    help="gradient chunk count of each local SGD step "
                         "(DESIGN.md §11): the per-step gradient is the "
                         "canonical halving-tree mean over this many equal "
                         "batch chunks; on a mesh whose data-axis size "
                         "matches, chunks run one-per-device over the data "
                         "axis with bitwise-identical histories (1 = plain "
                         "value_and_grad, the seed semantics)")
    ap.add_argument("--update-impl", default="",
                    choices=["", "auto", "reference", "kernel", "kernel_interpret"],
                    help="pFedSOP round-start update impl (DESIGN.md §9): "
                         "fused Pallas kernel vs pytree reference; '' defers "
                         "to the method config (auto: kernel on TPU). "
                         "kernel_interpret runs the kernel body on CPU")
    ap.add_argument("--model", choices=["small", "resnet9"], default="small")
    ap.add_argument("--paper-scale", action="store_true",
                    help="K=100 clients, 20%% participation, 100 rounds (slow on CPU)")
    # -- async federation (DESIGN.md §10) ---------------------------------
    ap.add_argument("--mode", choices=["sync", "async"], default="sync",
                    help="sync: bulk-synchronous rounds (the paper's setup); "
                         "async: availability-aware discrete-event simulation "
                         "with FedBuff-style buffered staleness-weighted "
                         "aggregation (DESIGN.md §10). 'rounds' then counts "
                         "applied server updates")
    ap.add_argument("--buffer-size", type=int, default=0,
                    help="async: uploads per server update (0 = K', the "
                         "sync-degenerate setting)")
    ap.add_argument("--concurrency", type=int, default=0,
                    help="async: clients kept in flight (0 = K')")
    ap.add_argument("--speed", choices=["fixed", "lognormal"], default="fixed",
                    help="per-client compute-speed model (both modes: async "
                         "scheduling / sync simulated round clock)")
    ap.add_argument("--speed-sigma", type=float, default=1.0,
                    help="lognormal sigma of the per-client speed multipliers")
    ap.add_argument("--mean-duration", type=float, default=1.0,
                    help="median simulated client round duration (sim seconds)")
    ap.add_argument("--availability", default="1.0",
                    help="either a steady-state online fraction per client "
                         "(float; 1.0 = always on, exponential on/off "
                         "traces) or 'trace:<path>' to replay a recorded "
                         "device trace file (JSON on/off windows + "
                         "durations; see examples/traces/)")
    ap.add_argument("--mean-on", type=float, default=10.0,
                    help="mean online-stretch length (sim seconds)")
    # -- cohort store (DESIGN.md §12) --------------------------------------
    ap.add_argument("--store", choices=["device", "host", "mmap"],
                    default="device",
                    help="where per-client personalized state lives at rest: "
                         "'device' = one stacked device array (the seed "
                         "layout), 'host' = numpy in host RAM, 'mmap' = "
                         "disk-backed memmap; host/mmap gather only each "
                         "round's participants to device, so --clients is a "
                         "throughput knob instead of a device-memory limit — "
                         "bitwise identical results either way")
    ap.add_argument("--cache-clients", type=int, default=0,
                    help="host/mmap stores only: keep device rows of the N "
                         "most recently sampled clients in an LRU cache, "
                         "skipping their host->device copy on re-sampling "
                         "(0 = no cache)")
    # -- observability (DESIGN.md §13) -------------------------------------
    ap.add_argument("--trace-dir", default="",
                    help="write a structured event trace under this directory "
                         "(per-method subdirs, like --ckpt-dir); the drivers "
                         "export a Perfetto-loadable trace.json on completion "
                         "and scripts/trace_report.py summarizes it. "
                         "Fingerprint-stamped: re-running a --resume'd config "
                         "appends with a resume marker instead of clobbering")
    ap.add_argument("--metrics", default="",
                    help="metrics.jsonl path ('' = <trace-dir>/<method>/"
                         "metrics.jsonl when tracing); counters/gauges/"
                         "histograms snapshot once per applied server update")
    ap.add_argument("--obs-level", choices=["off", "round", "phase", "kernel"],
                    default="phase",
                    help="instrumentation depth (DESIGN.md §13): round = "
                         "round spans + metrics; phase = + per-phase spans "
                         "with block-until-ready boundaries; kernel = + "
                         "jax.profiler annotations around kernel launches")
    ap.add_argument("--xla-profile", type=int, default=-1,
                    help="capture a jax.profiler trace of this round/version "
                         "index under <trace-dir>/<method>/xla (-1 = off; "
                         "1 is the first post-compile round)")
    ap.add_argument("--obs-quiet", action="store_true",
                    help="suppress the drivers' stdout progress lines "
                         "(structured records still land in the trace)")
    # -- checkpointing ----------------------------------------------------
    ap.add_argument("--ckpt-every", type=int, default=0,
                    help="checkpoint the full driver state every N applied "
                         "server updates (0 = off); see repro.utils.checkpoint")
    ap.add_argument("--ckpt-dir", default="",
                    help="checkpoint directory (per-method subdirs)")
    ap.add_argument("--resume", action="store_true",
                    help="resume each method from its latest checkpoint under "
                         "--ckpt-dir (bitwise-identical continuation)")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="legacy: save only the final broadcast per method")
    ap.add_argument("--tag", default="run")
    args = ap.parse_args()

    if args.resume and not args.ckpt_dir:
        ap.error("--resume requires --ckpt-dir")
    if args.ckpt_every and not args.ckpt_dir:
        ap.error("--ckpt-every requires --ckpt-dir (the drivers only save "
                 "when both are set, so checkpointing would be silently off)")
    if args.mode != "async" and (args.buffer_size or args.concurrency):
        ap.error("--buffer-size/--concurrency only apply to --mode async "
                 "(the sync driver has no aggregation buffer or dispatch "
                 "pipeline), so they would be silently ignored")
    if args.output_sharding == "sharded" and args.backend == "vmap":
        ap.error("--output-sharding sharded needs a client-sharding backend "
                 "(--backend shard_map or mesh); vmap outputs are born "
                 "replicated, so the flag would be a silent no-op")
    if args.mesh and args.backend != "mesh":
        ap.error("--mesh only applies to --backend mesh (the other backends "
                 "fix their own layout), so it would be silently ignored")
    if args.backend == "mesh" and not args.mesh:
        ap.error("--backend mesh requires --mesh (e.g. 'pods:2x2x2'); see "
                 "repro.launch.mesh.parse_mesh for the grammar")
    if args.xla_profile >= 0 and not args.trace_dir:
        ap.error("--xla-profile dumps under <trace-dir>/<method>/xla, so it "
                 "requires --trace-dir")
    if args.obs_level == "off" and (args.trace_dir or args.metrics):
        ap.error("--obs-level off disables every sink, so --trace-dir/"
                 "--metrics would be silently ignored")
    if args.metrics and len(args.methods) > 1:
        ap.error("--metrics names a single file; each of the "
                 f"{len(args.methods)} --methods would clobber it — use "
                 "--trace-dir (per-method metrics.jsonl subdirs) instead")
    if args.cache_clients and args.store == "device":
        ap.error("--cache-clients only applies to --store host/mmap (the "
                 "device store keeps every client resident, so a device "
                 "cache is meaningless), so it would be silently ignored")

    trace_path = None
    if args.availability.startswith("trace:"):
        trace_path = args.availability[len("trace:"):]
        if (args.speed != "fixed" or args.speed_sigma != 1.0
                or args.mean_duration != 1.0 or args.mean_on != 10.0):
            ap.error("--availability trace:<path> replays durations and "
                     "on/off windows from the file; --speed/--speed-sigma/"
                     "--mean-duration/--mean-on would be silently ignored")
    else:
        try:
            args.availability = float(args.availability)
        except ValueError:
            ap.error(f"--availability must be a float or 'trace:<path>', "
                     f"got {args.availability!r}")

    if args.update_impl and not any(m.startswith("pfedsop") for m in args.methods):
        ap.error("--update-impl targets the pFedSOP round-start update; none of "
                 f"--methods {args.methods} has a kernel dispatch path "
                 "(DESIGN.md §9), so the flag would be a silent no-op")

    if args.paper_scale:
        args.clients, args.participation, args.rounds = 100, 0.2, 100
        args.samples = 20000

    cfg = SMALL_CNN if args.model == "small" else RESNET9_CIFAR100
    cfg = cfg.replace(n_classes=args.classes, cnn_image_size=args.image_size)

    print(f"dataset: {args.samples} samples, {args.classes} classes, "
          f"{args.partition} partition across {args.clients} clients")
    images, labels = make_class_conditional_images(
        args.samples, args.classes, args.image_size, seed=args.seed)
    if args.partition == "dirichlet":
        parts = dirichlet_partition(labels, args.clients, args.alpha, seed=args.seed)
    else:
        parts = pathological_partition(labels, args.clients, args.shard_size,
                                       seed=args.seed)
    data = FederatedData.from_partition(images, labels, parts, seed=args.seed)

    loss = lambda p, b: cnn.loss_fn(p, cfg, b)
    acc = masked_accuracy(lambda p, t: cnn.apply(p, cfg, t["images"]))
    params = cnn.init_params(jax.random.PRNGKey(args.seed), cfg)  # same init for all

    if trace_path is not None:
        avail_cfg = TraceAvailabilityConfig(path=trace_path)
    else:
        avail_cfg = AvailabilityConfig(
            speed=args.speed, mean_duration=args.mean_duration,
            sigma=args.speed_sigma, availability=args.availability,
            mean_on=args.mean_on,
        )
    async_cfg = AsyncConfig(
        buffer_size=args.buffer_size, concurrency=args.concurrency,
        availability=avail_cfg,
    )
    run_cfg = FLRunConfig(
        n_clients=args.clients, participation=args.participation,
        rounds=args.rounds, batch=args.batch, seed=args.seed,
        backend=args.backend, shards=args.shards, mesh=args.mesh,
        output_sharding=args.output_sharding, grad_chunks=args.grad_chunks,
        update_impl=args.update_impl,
        ckpt_every=args.ckpt_every,
        async_cfg=async_cfg,
        store=StoreConfig(kind=args.store, cache_clients=args.cache_clients),
    )

    out_dir = Path("experiments/fl")
    out_dir.mkdir(parents=True, exist_ok=True)
    results = {}
    for name in args.methods:
        # --update-impl targets the pFedSOP round-start update; baselines
        # have no kernel dispatch path, so the override stays off for them
        # (an FLRunConfig-level override on a knob-less method is an error).
        cfg_m = run_cfg if name.startswith("pfedsop") else replace(run_cfg, update_impl="")
        if args.ckpt_dir:
            cfg_m = replace(cfg_m, ckpt_dir=str(Path(args.ckpt_dir) / name))
        if args.trace_dir or args.metrics or args.obs_quiet:
            cfg_m = replace(cfg_m, obs=ObsConfig(
                trace_dir=(str(Path(args.trace_dir) / name)
                           if args.trace_dir else ""),
                metrics=args.metrics, level=args.obs_level,
                quiet=args.obs_quiet, xla_profile=args.xla_profile))
        method = build_method(name, args.lr, args)
        if args.mode == "async":
            fed = AsyncFederation(method, loss, acc, params, data, cfg_m)
        else:
            # the sync driver stays availability-oblivious (it samples and
            # waits for stragglers) but uses the same heterogeneity model
            # for its simulated clock, so sim_time is comparable
            model = make_availability(avail_cfg, args.clients, args.seed)
            fed = Federation(method, loss, acc, params, data, cfg_m,
                             availability=model)
        if args.resume and latest_step(cfg_m.ckpt_dir) is not None:
            at = fed.restore()
            fed.obs.log.info(
                f"[{name}] resumed from {cfg_m.ckpt_dir} at round {at}",
                event="resume_notice", method=name, round=int(at))
        hist = fed.run(verbose=True)
        results[name] = hist
        fed.obs.log.info(
            f"--> {name}: mean best acc {hist['mean_best_acc']:.4f}, "
            f"mean round time {np.mean(hist['round_time'][1:]):.2f}s, "
            f"sim wall-clock {hist['sim_time'][-1]:.1f}",
            event="method_summary", method=name,
            mean_best_acc=float(hist["mean_best_acc"]))
        if args.checkpoint_dir:
            save_checkpoint(Path(args.checkpoint_dir) / name, args.rounds,
                            {"broadcast": fed.broadcast},
                            extra={"mean_best_acc": hist["mean_best_acc"]})

    tag = f"{args.tag}_{args.partition}_{args.clients}c_{args.rounds}r"
    payload = {"args": vars(args), "results": results}
    (out_dir / f"{tag}.json").write_text(json.dumps(payload, indent=1))
    print(f"\nwrote experiments/fl/{tag}.json")
    print(f"{'method':>14} {'best_acc':>9} {'final_loss':>11}")
    for name, h in results.items():
        print(f"{name:>14} {h['mean_best_acc']:>9.4f} {h['loss'][-1]:>11.4f}")


if __name__ == "__main__":
    main()
