"""Serving example: batched autoregressive decode through the framework's
serve path (KV caches / SSM recurrent state), CPU-sized.

Serves a reduced variant of any assigned architecture: prefill a batch of
prompts, then decode greedily - the same decode_step the dry-run lowers at
(arch x decode_32k / long_500k) production shapes.

  PYTHONPATH=src python examples/serve_decode.py --arch gemma3-1b --tokens 16
  PYTHONPATH=src python examples/serve_decode.py --arch mamba2-2.7b   # O(1)-state decode
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_NAMES, get_config
from repro.models import transformer as tf


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_NAMES), default="gemma3-1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--kernel-impl", default="auto",
                    choices=["auto", "reference", "kernel", "kernel_interpret"],
                    help="model-zoo kernel policy (rmsnorm/flash_gqa, "
                         "DESIGN.md §9); auto = kernel on TPU")
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=True).replace(kernel_impl=args.kernel_impl)
    print(f"serving {cfg.name}: {cfg.n_layers}L d={cfg.d_model} "
          f"family={cfg.family} vocab={cfg.vocab_size}")
    params = tf.init_params(jax.random.PRNGKey(args.seed), cfg)

    key = jax.random.PRNGKey(args.seed + 1)
    b, pl_, total = args.batch, args.prompt_len, args.prompt_len + args.tokens
    if cfg.frontend == "audio_codebooks":
        prompts = jax.random.randint(key, (b, cfg.n_codebooks, pl_), 0, cfg.vocab_size)
    else:
        prompts = jax.random.randint(key, (b, pl_), 0, cfg.vocab_size)

    caches = tf.init_caches(cfg, b, total)

    @jax.jit
    def decode_one(params, tok, pos, caches):
        batch = {"tokens": tok}
        if cfg.frontend == "vision_stub":
            batch["patch_embeds"] = jnp.zeros((b, 0, cfg.d_vision), jnp.float32)
        logits, caches = tf.decode_step(params, cfg, batch, pos, caches)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return nxt, caches

    # prefill token-by-token (the production path prefills via forward();
    # here we exercise the cache ring-buffers end to end)
    t0 = time.perf_counter()
    out_tokens = []
    for t in range(pl_):
        tok = prompts[:, :, t:t+1] if cfg.frontend == "audio_codebooks" else prompts[:, t:t+1]
        nxt, caches = decode_one(params, tok, jnp.asarray(t, jnp.int32), caches)
    cur = nxt[..., None] if cfg.frontend != "audio_codebooks" else jnp.broadcast_to(
        nxt[..., None, None], (b, cfg.n_codebooks, 1)).astype(jnp.int32)
    for t in range(pl_, total):
        out_tokens.append(np.asarray(cur))
        nxt, caches = decode_one(params, cur, jnp.asarray(t, jnp.int32), caches)
        cur = nxt[..., None] if cfg.frontend != "audio_codebooks" else jnp.broadcast_to(
            nxt[..., None, None], (b, cfg.n_codebooks, 1)).astype(jnp.int32)
    dt = time.perf_counter() - t0

    gen = np.concatenate(out_tokens, axis=-1)
    print(f"decoded {args.tokens} tokens x {b} sequences in {dt:.2f}s "
          f"({args.tokens * b / dt:.1f} tok/s incl. prefill + compile)")
    print("sample token ids:", gen.reshape(b, -1)[:, :10])
    assert np.all(gen >= 0) and np.all(gen < cfg.vocab_size)
    print("OK")


if __name__ == "__main__":
    main()
