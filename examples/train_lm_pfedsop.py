"""Federated LM training with pFedSOP over the assigned transformer archs.

Four simulated organizations ("cross-silo" FL), each with its own Markov
token distribution (heterogeneity analog), collaboratively train reduced
variants of an assigned architecture with the pFedSOP optimizer - the
CPU-scale mirror of the multi-pod deployment lowered by dryrun.py.

  PYTHONPATH=src python examples/train_lm_pfedsop.py --arch granite-3-2b --rounds 10
  PYTHONPATH=src python examples/train_lm_pfedsop.py --arch olmoe-1b-7b   # MoE path
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_NAMES, get_config
from repro.core import pfedsop as pf
from repro.data import lm_batch_iterator, synthetic_lm_stream
from repro.models import transformer as tf
from repro.obs import get_obs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_NAMES), default="granite-3-2b")
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--local-iters", type=int, default=4)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--eta", type=float, default=0.1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--kernel-impl", default="auto",
                    choices=["auto", "reference", "kernel", "kernel_interpret"],
                    help="model-zoo kernel policy (rmsnorm/flash_gqa, "
                         "DESIGN.md §9): reference vs kernel_interpret on the "
                         "same seed produces identical loss histories")
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=True).replace(kernel_impl=args.kernel_impl)
    if cfg.frontend != "none":
        raise SystemExit(f"{args.arch} needs a modality frontend; this example "
                         "covers the text archs (see serve_decode.py for the rest)")
    pcfg = pf.PFedSOPConfig(eta1=args.eta, eta2=args.eta, rho=1.0, lam=1.0)

    print(f"pFedSOP x {cfg.name}: {args.clients} clients, {args.rounds} rounds, "
          f"kernel_impl={cfg.kernel_impl}")
    params = tf.init_params(jax.random.PRNGKey(args.seed), cfg)
    n_params = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
    print(f"params: {n_params/1e6:.2f}M")

    # per-client heterogeneous token streams
    iters = [
        lm_batch_iterator(
            synthetic_lm_stream(20_000, cfg.vocab_size, seed=100 + i, branch=3),
            args.batch, args.seq_len, seed=i)
        for i in range(args.clients)
    ]

    loss_fn = lambda p, b: tf.lm_loss(p, cfg, b)
    states = [pf.init_client_state(params) for _ in range(args.clients)]
    global_delta = jax.tree.map(jnp.zeros_like, params)
    has_global = jnp.asarray(False)

    round_fn = jax.jit(
        lambda s, gd, hg, b: pf.client_round(loss_fn, s, gd, hg, b, pcfg)
    )

    for t in range(args.rounds):
        t0 = time.perf_counter()
        deltas, losses, betas = [], [], []
        for i in range(args.clients):
            bs = [next(iters[i]) for _ in range(args.local_iters)]
            batches = jax.tree.map(lambda *xs: jnp.stack(xs), *bs)
            states[i], delta, m = round_fn(states[i], global_delta, has_global, batches)
            deltas.append(delta)
            losses.append(float(m["loss"]))
            betas.append(float(m["beta"]))
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *deltas)
        global_delta, has_global = pf.server_aggregate(stacked), jnp.asarray(True)
        # routed through the obs structured logger (quiet-able; mirrors
        # into an open trace); the 6-decimal loss format is load-bearing —
        # the impl-parity test reads histories off these lines
        get_obs().log.info(
            f"round {t:3d} loss={np.mean(losses):.6f} "
            f"beta={np.mean(betas):.3f} ({time.perf_counter()-t0:.1f}s)",
            event="round", round=t, loss=float(np.mean(losses)),
            beta=float(np.mean(betas)))

    assert np.isfinite(np.mean(losses))
    print("OK: federated LM training ran end-to-end "
          f"(final mean loss {np.mean(losses):.4f})")


if __name__ == "__main__":
    main()
