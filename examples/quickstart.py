"""Quickstart: the pFedSOP optimizer on a 2-client toy problem.

Shows the paper's three moving parts in ~40 lines of user code:
  1. Gompertz-weighted personalized aggregation of local/global updates
  2. Sherman-Morrison second-order step on the regularized FIM
  3. local SGD + server aggregation of gradient updates

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import pfedsop as pf

# two clients with different optima - a miniature "heterogeneous federation"
TARGETS = [2.0, -1.0]


def make_loss(target):
    def loss_fn(params, batch):
        noise = batch  # (batch_size,) pseudo-noise, keeps SGD stochastic
        err = params["w"][None, :] - target + 0.01 * noise[:, None]
        return 0.5 * jnp.mean(err**2)
    return loss_fn


def main():
    cfg = pf.PFedSOPConfig(eta1=0.8, eta2=0.2, rho=1.0, lam=1.0)
    params = {"w": jnp.zeros((4,))}
    states = [pf.init_client_state(params) for _ in TARGETS]
    global_delta = {"w": jnp.zeros((4,))}
    has_global = jnp.asarray(False)

    key = jax.random.PRNGKey(0)
    print(f"{'round':>5} {'client0 w[0]':>12} {'client1 w[0]':>12} {'beta0':>7}")
    for t in range(25):
        deltas, metrics = [], []
        for i, target in enumerate(TARGETS):
            key, sub = jax.random.split(key)
            batches = jax.random.normal(sub, (5, 8))  # 5 local SGD iterations
            states[i], delta, m = pf.client_round(
                make_loss(target), states[i], global_delta, has_global, batches, cfg
            )
            deltas.append(delta)
            metrics.append(m)
        # server: Eq. 13
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *deltas)
        global_delta, has_global = pf.server_aggregate(stacked), jnp.asarray(True)
        if t % 5 == 0 or t == 24:
            print(f"{t:>5} {float(states[0].params['w'][0]):>12.4f} "
                  f"{float(states[1].params['w'][0]):>12.4f} "
                  f"{float(metrics[0]['beta']):>7.3f}")

    for i, target in enumerate(TARGETS):
        err = float(jnp.max(jnp.abs(states[i].params["w"] - target)))
        print(f"client {i}: |w - {target}| = {err:.4f} (personalized, not the global mean)")
        assert err < 0.2, "personalization failed"
    print("OK: each client converged to ITS OWN optimum under collaboration.")


if __name__ == "__main__":
    main()
