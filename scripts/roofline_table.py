"""Build the §Roofline markdown table from experiments/dryrun artifacts.

Adds MODEL_FLOPS = 6*N_active*D (train) / 2*N_active*D (inference) and the
usefulness ratio MODEL_FLOPS / HLO_FLOPs (catches remat/dense-MoE waste).

  PYTHONPATH=src python scripts/roofline_table.py [--mesh 16x16] [--md out.md]
"""
import argparse
import json
from pathlib import Path

from repro.configs import ARCH_NAMES, INPUT_SHAPES, get_config
from repro.launch.roofline import model_flops
from repro.launch.steps import resolve_cfg

ROOT = Path(__file__).resolve().parents[1] / "experiments"
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(mesh, variant="baseline", calibrated=False, art_dir=None):
    art = Path(art_dir) if art_dir else (ROOT / ("roofline" if calibrated else "dryrun"))
    rows = []
    for arch in ARCH_NAMES:
        for shape in SHAPE_ORDER:
            tag = f"{arch}__{shape}__{mesh}"
            if variant != "baseline":
                tag += f"__{variant}"
            f = art / f"{tag}.json"
            if not f.exists():
                continue
            r = json.loads(f.read_text())
            cfg = resolve_cfg(get_config(arch), INPUT_SHAPES[shape])
            mf = model_flops(cfg, INPUT_SHAPES[shape])
            rl = r["roofline"]
            tot = r.get("total_flops", rl.get("total_flops", 0.0))
            ratio = mf / tot if tot else 0.0
            if "memory_analysis" in r:
                hbm_gb = (r["memory_analysis"]["argument_size_in_bytes"]
                          + r["memory_analysis"]["output_size_in_bytes"]
                          + r["memory_analysis"]["temp_size_in_bytes"]) / 1e9
            else:
                hbm_gb = float("nan")
            rows.append({
                "arch": arch, "shape": shape, "mesh": mesh,
                "compute_s": rl["compute_s"], "memory_s": rl["memory_s"],
                "collective_s": rl["collective_s"], "dominant": rl["dominant"],
                "model_flops": mf, "hlo_flops": tot,
                "useful_ratio": ratio, "hbm_gb_per_dev": hbm_gb,
                "compile_s": r.get("compile_s", r.get("calibrate_s")),
            })
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--calibrated", action="store_true")
    ap.add_argument("--art-dir", default=None)
    ap.add_argument("--md", default=None)
    args = ap.parse_args()

    rows = load(args.mesh, args.variant, calibrated=args.calibrated,
                art_dir=args.art_dir)
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant | "
           "MODEL_FLOPS | HLO_FLOPs | useful | HBM GB/dev |")
    sep = "|" + "---|" * 10
    lines = [hdr, sep]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | {r['dominant']} | "
            f"{r['model_flops']:.3e} | {r['hlo_flops']:.3e} | "
            f"{r['useful_ratio']:.2f} | {r['hbm_gb_per_dev']:.1f} |"
        )
    table = "\n".join(lines)
    print(table)

    # hillclimb-candidate ranking
    print("\n-- candidates --")
    tr = [r for r in rows if r["shape"] == "train_4k"]
    worst = sorted(tr, key=lambda r: r["useful_ratio"])[:3]
    coll = sorted(rows, key=lambda r: -r["collective_s"] /
                  max(1e-12, max(r["compute_s"], r["memory_s"])))[:3]
    print("worst useful ratio:", [(r["arch"], r["shape"], round(r["useful_ratio"], 2)) for r in worst])
    print("most collective-bound:", [(r["arch"], r["shape"],
          round(r["collective_s"] / max(r["compute_s"], r["memory_s"]), 2)) for r in coll])
    if args.md:
        Path(args.md).write_text(table + "\n")


if __name__ == "__main__":
    main()
