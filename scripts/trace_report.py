"""Summarize obs traces: per-phase breakdown, stragglers, bytes, dists.

Reads the ``events.jsonl`` / ``metrics.jsonl`` a traced run leaves under
its ``--trace-dir`` (DESIGN.md §13) and prints the questions the trace
exists to answer:

- **phase breakdown** — wall-clock per server phase (gather / client /
  all_gather / eval / aggregate / scatter, plus the async dispatch
  pipeline), warm means with the compile round excluded, as a share of
  round time.  Pointed at several runs at once (e.g. the per-backend
  subdirs ``benchmarks/run.py --only multipod-engine --trace-dir ...``
  leaves behind) it prints a side-by-side comparison — the
  shard_map-vs-mesh gap decomposes into per-phase deltas, with the
  round-boundary all-gather visible as its own line.
- **stragglers** — top-k clients by total in-flight sim time (the async
  scheduler's dispatch→completion spans).
- **bytes moved** — the cohort store's h2d/d2h counters from the final
  metrics snapshot.
- **distributions** — the recorded histograms (pFedSOP angle θ, β,
  client loss, async staleness τ and its Gompertz discount).

  PYTHONPATH=src python scripts/trace_report.py <trace-dir> [...] \
      [--top-k 5] [--json report.json]

A directory without its own ``events.jsonl`` is searched for traced runs
beneath it, so pointing at a bench harness --trace-dir root reports every
run it contains.
"""
from __future__ import annotations

import argparse
import json
import sys
from collections import defaultdict
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.obs import read_events, read_metrics  # noqa: E402

# server phases in pipeline order; anything else recorded lands after
PHASE_ORDER = ["gather", "client", "all_gather", "eval", "aggregate",
               "aggregate_stale", "scatter", "train_step", "round"]


def discover(paths):
    """Expand each path to the traced runs at or beneath it."""
    runs = []
    for p in paths:
        p = Path(p)
        if (p / "events.jsonl").exists():
            runs.append(p)
        else:
            runs.extend(sorted(q.parent for q in p.rglob("events.jsonl")))
    return runs


def _phase_stats(events):
    """name -> {count, total_us, warm_mean_us} over span records.

    The first occurrence of each phase carries jit compilation, so the
    warm mean (all occurrences after the first) is the honest per-round
    figure; ``total`` keeps compile time so shares still add up.
    """
    durs = defaultdict(list)
    for rec in events:
        if rec.get("k") == "span" and "dur" in rec:
            durs[rec["name"]].append(int(rec["dur"]))
    out = {}
    for name, ds in durs.items():
        warm = ds[1:] if len(ds) > 1 else ds
        out[name] = {
            "count": len(ds),
            "total_us": sum(ds),
            "warm_mean_us": sum(warm) / len(warm),
        }
    return out


def _stragglers(events, top_k):
    """Top-k clients by total in-flight sim time (+ dispatch count)."""
    total = defaultdict(float)
    count = defaultdict(int)
    for rec in events:
        if rec.get("k") == "cspan" and rec.get("name") == "inflight":
            total[rec["client"]] += rec["sim1"] - rec["sim0"]
            count[rec["client"]] += 1
    ranked = sorted(total, key=total.get, reverse=True)[:top_k]
    return [{"client": c, "inflight_sim_s": total[c], "dispatches": count[c]}
            for c in ranked]


def _compile_events(events):
    return [
        {"name": r["name"], **r.get("args", {})}
        for r in events
        if r.get("k") == "ev" and r.get("cat") == "compile"
    ]


def _last_snapshot(run):
    path = run / "metrics.jsonl"
    if not path.exists():
        return None
    snaps = read_metrics(path)
    return snaps[-1] if snaps else None


def _fmt_hist(name, h, width=28):
    if not h.get("count"):
        # registered but never observed (e.g. a sharded-output run records
        # no replicate-phase histogram): min/max are None — render, don't
        # crash on the float format
        return [f"  {name}: n=0 mean=— min=— max=—"]
    lines = [f"  {name}: n={h['count']} mean={h['sum'] / max(h['count'], 1):.4g} "
             f"min={h['min']:.4g} max={h['max']:.4g}"]
    edges = h["edges"]
    labels = ([f"<{edges[0]:g}"]
              + [f"[{a:g},{b:g})" for a, b in zip(edges, edges[1:])]
              + [f">={edges[-1]:g}"])
    peak = max(h["counts"]) or 1
    for label, n in zip(labels, h["counts"]):
        if n:
            bar = "#" * max(1, round(width * n / peak))
            lines.append(f"    {label:>14} {n:>7} {bar}")
    return lines


def report_run(run, top_k):
    events = read_events(run)
    meta_path = run / "meta.json"
    meta = json.loads(meta_path.read_text()) if meta_path.exists() else {}
    phases = _phase_stats(events)
    snap = _last_snapshot(run)
    rep = {
        "trace_dir": str(run),
        "fingerprint": meta.get("fingerprint"),
        "events": len(events),
        "resumes": sum(1 for r in events
                       if r.get("k") == "ev" and r.get("name") == "resume"),
        "phases": phases,
        "stragglers": _stragglers(events, top_k),
        "compile_events": _compile_events(events),
    }
    if snap is not None:
        gauges = snap.get("gauges", {})
        rep["bytes_moved"] = {
            k.split(".", 1)[1]: gauges[k]
            for k in ("store.h2d_bytes", "store.d2h_bytes") if k in gauges}
        rep["histograms"] = snap.get("histograms", {})
        rep["counters"] = snap.get("counters", {})
    return rep


def print_run(rep):
    fp = rep["fingerprint"] or {}
    tag = " ".join(f"{k}={fp[k]}" for k in ("driver", "backend", "method")
                   if isinstance(fp, dict) and k in fp)
    print(f"\n== {rep['trace_dir']} {('(' + tag + ')') if tag else ''}")
    print(f"  {rep['events']} events, {rep['resumes']} resume(s), "
          f"{len(rep['compile_events'])} compile event(s)")

    phases = rep["phases"]
    if phases:
        round_warm = phases.get("round", {}).get("warm_mean_us", 0)
        print(f"  {'phase':>16} {'count':>6} {'warm mean ms':>13} "
              f"{'total s':>8} {'% of round':>10}")
        names = ([n for n in PHASE_ORDER if n in phases]
                 + sorted(set(phases) - set(PHASE_ORDER)))
        for name in names:
            st = phases[name]
            share = (100 * st["warm_mean_us"] / round_warm
                     if round_warm and name != "round" else None)
            print(f"  {name:>16} {st['count']:>6} "
                  f"{st['warm_mean_us'] / 1e3:>13.2f} "
                  f"{st['total_us'] / 1e6:>8.2f} "
                  + (f"{share:>9.1f}%" if share is not None else f"{'—':>10}"))

    if rep["stragglers"]:
        print("  stragglers (total in-flight sim time):")
        for s in rep["stragglers"]:
            print(f"    client {s['client']:>6}: {s['inflight_sim_s']:>8.2f}s "
                  f"over {s['dispatches']} dispatches")

    if rep.get("bytes_moved"):
        moved = ", ".join(f"{k}={v / 1e6:.1f}MB"
                          for k, v in rep["bytes_moved"].items())
        print(f"  bytes moved: {moved}")
    if rep.get("counters"):
        print("  counters: " + ", ".join(
            f"{k}={v}" for k, v in sorted(rep["counters"].items())))
    for name, h in sorted(rep.get("histograms", {}).items()):
        for line in _fmt_hist(name, h):
            print(line)


def print_comparison(reps):
    """Side-by-side warm phase means — the cross-backend gap, attributed."""
    all_phases = set()
    for rep in reps:
        all_phases |= set(rep.get("phases", {}))
    names = ([n for n in PHASE_ORDER if n in all_phases]
             + sorted(all_phases - set(PHASE_ORDER)))
    cols = [Path(rep["trace_dir"]).name[:22] for rep in reps]
    print("\n== phase comparison (warm mean ms) ==")
    print(f"  {'phase':>16} " + " ".join(f"{c:>22}" for c in cols))
    for name in names:
        # a run may simply not record a phase (sharded-output runs have no
        # all_gather/replicate span) — render "—", never KeyError
        row = []
        for rep in reps:
            st = rep.get("phases", {}).get(name)
            row.append(f"{st['warm_mean_us'] / 1e3:>22.2f}" if st
                       else f"{'—':>22}")
        print(f"  {name:>16} " + " ".join(row))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("trace_dirs", nargs="+",
                    help="trace dir(s), or roots containing traced runs")
    ap.add_argument("--top-k", type=int, default=5,
                    help="stragglers to list per run")
    ap.add_argument("--json", default="",
                    help="also write the full structured report here")
    args = ap.parse_args()

    runs = discover(args.trace_dirs)
    if not runs:
        raise SystemExit(f"no events.jsonl found under {args.trace_dirs}")
    reps = [report_run(run, args.top_k) for run in runs]
    for rep in reps:
        print_run(rep)
    if len(reps) > 1:
        print_comparison(reps)
    if args.json:
        Path(args.json).write_text(json.dumps(reps, indent=1, default=str))
        print(f"\nwrote {args.json}")


if __name__ == "__main__":
    main()
