"""Quick dev smoke: every reduced arch does a forward + loss + decode step."""
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_NAMES, get_config
from repro.models import transformer as tf


def make_batch(cfg, b=2, s=32, key=None):
    key = key or jax.random.PRNGKey(0)
    if cfg.frontend == "audio_codebooks":
        toks = jax.random.randint(key, (b, cfg.n_codebooks, s), 0, cfg.vocab_size)
        return {"tokens": toks, "labels": toks}
    if cfg.frontend == "vision_stub":
        toks = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
        pe = jax.random.normal(key, (b, cfg.n_patches, cfg.d_vision), jnp.float32)
        return {"tokens": toks, "labels": toks, "patch_embeds": pe}
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    return {"tokens": toks, "labels": toks}


def main():
    names = sys.argv[1:] or ARCH_NAMES
    for name in names:
        cfg = get_config(name, reduced=True)
        params = tf.init_params(jax.random.PRNGKey(0), cfg)
        batch = make_batch(cfg)
        loss = jax.jit(lambda p, b: tf.lm_loss(p, cfg, b))(params, batch)
        assert np.isfinite(float(loss)), f"{name}: loss {loss}"
        # decode one token
        caches = tf.init_caches(cfg, 2, 64)
        db = dict(batch)
        if cfg.frontend == "audio_codebooks":
            db["tokens"] = batch["tokens"][:, :, :1]
        elif cfg.frontend == "vision_stub":
            db["tokens"] = batch["tokens"][:, :1]
            db["patch_embeds"] = batch["patch_embeds"][:, :0]
        else:
            db["tokens"] = batch["tokens"][:, :1]
        db.pop("labels", None)
        logits, _ = tf.decode_step(params, cfg, db, jnp.asarray(0, jnp.int32), caches)
        assert np.all(np.isfinite(np.asarray(logits, np.float32))), name
        print(f"{name:24s} loss={float(loss):.4f} decode_logits={logits.shape} OK")


if __name__ == "__main__":
    main()
