"""Dev smoke: tiny federation, pFedSOP vs FedAvg, a few rounds."""
import functools

import jax
import numpy as np

from repro.configs.resnet_cifar import SMALL_CNN
from repro.core.baselines import METHODS
from repro.data import FederatedData, dirichlet_partition, make_class_conditional_images
from repro.fl import Federation, FLRunConfig
from repro.fl.runtime import masked_accuracy
from repro.models import cnn


def main():
    cfg = SMALL_CNN
    images, labels = make_class_conditional_images(2000, cfg.n_classes, cfg.cnn_image_size, seed=0)
    parts = dirichlet_partition(labels, 10, alpha=0.3, seed=0)
    data = FederatedData.from_partition(images, labels, parts, seed=0)

    loss_fn = functools.partial(cnn.loss_fn, cfg=cfg)
    loss = lambda p, b: cnn.loss_fn(p, cfg, b)
    acc = masked_accuracy(lambda p, t: cnn.apply(p, cfg, t["images"]))
    params = cnn.init_params(jax.random.PRNGKey(0), cfg)

    run_cfg = FLRunConfig(n_clients=10, participation=0.4, rounds=6, batch=20, seed=0)
    for name in ["pfedsop", "fedavg"]:
        method = METHODS[name]()
        fed = Federation(method, loss, acc, params, data, run_cfg)
        hist = fed.run(verbose=True)
        print(name, "mean_best_acc", hist["mean_best_acc"])
        assert np.isfinite(hist["loss"][-1])


if __name__ == "__main__":
    main()
