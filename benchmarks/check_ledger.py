"""Bench-ledger regression gate for the perf trajectory (ISSUE 7).

Compares a freshly emitted ``experiments/bench/BENCH_<suite>.json`` against
the committed trajectory under ``benchmarks/ledger/`` and fails when any
throughput entry (``rounds_per_sec`` — federation suites — or
``tokens_per_sec`` — the model fwd/bwd suites) drops below ``--min-ratio``
(default 0.3) of the ledger value.  The threshold is deliberately loose: CI boxes are noisy and
the gate exists to catch order-of-magnitude regressions (an accidental
de-jit, a cache that stopped caching, a gather gone quadratic), not
percent-level drift.  Entries present in only one file are reported but
never fail the gate — the sweep grid may grow.

  PYTHONPATH=src python -m benchmarks.run --only cohort-store ...
  python benchmarks/check_ledger.py cohort-store [--min-ratio 0.3]

The ``obs-overhead`` suite (DESIGN.md §13) additionally gates the
observability contract on the FRESH run: phase-level tracing must cost
< ``--max-overhead`` (default 5%) per round, and a run with observability
disabled must have written 0 bytes.  These are absolute gates, not
ledger ratios — the contract does not drift with the hardware.

Exit 0 on pass, 1 on regression, 2 when either file is missing.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]
LEDGER = REPO / "benchmarks" / "ledger"
FRESH = REPO / "experiments" / "bench"


THROUGHPUT_KEYS = ("rounds_per_sec", "tokens_per_sec")


def _throughputs(payload: dict, prefix=()) -> dict:
    """Flatten metrics to {dotted.path: throughput}.

    A node may carry at most one throughput key, so the dotted path stays
    unambiguous; the unit is implied by the suite (r/s for federation
    suites, tok/s for model-fwd/model-bwd).
    """
    out = {}
    node = payload.get("metrics", payload)
    stack = [(prefix, node)]
    while stack:
        path, cur = stack.pop()
        if not isinstance(cur, dict):
            continue
        for key, val in cur.items():
            if key in THROUGHPUT_KEYS and isinstance(val, (int, float)):
                out[".".join(path)] = float(val)
            elif isinstance(val, dict):
                stack.append((path + (str(key),), val))
    return out


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("suite", help="suite name, e.g. cohort-store")
    ap.add_argument("--min-ratio", type=float, default=0.3,
                    help="fail when fresh rounds/sec < min_ratio * ledger")
    ap.add_argument("--fresh", default="",
                    help="override the fresh BENCH json path")
    ap.add_argument("--max-overhead", type=float, default=0.05,
                    help="obs-overhead suite: fail when enabled-tracing "
                         "overhead_frac exceeds this (absolute gate)")
    args = ap.parse_args()

    ledger_path = LEDGER / f"BENCH_{args.suite}.json"
    fresh_path = Path(args.fresh) if args.fresh else (
        FRESH / f"BENCH_{args.suite}.json")
    for p, what in [(ledger_path, "committed ledger"), (fresh_path, "fresh run")]:
        if not p.exists():
            print(f"check_ledger: missing {what}: {p}", file=sys.stderr)
            return 2

    ledger = _throughputs(json.loads(ledger_path.read_text()))
    fresh = _throughputs(json.loads(fresh_path.read_text()))
    failures = []
    for key in sorted(set(ledger) | set(fresh)):
        if key not in ledger:
            print(f"  new entry (no ledger baseline): {key} "
                  f"{fresh[key]:.3f}")
            continue
        if key not in fresh:
            print(f"  ledger entry absent from fresh run: {key}")
            continue
        ratio = fresh[key] / ledger[key] if ledger[key] else float("inf")
        status = "OK" if ratio >= args.min_ratio else "REGRESSION"
        print(f"  {status:>10}  {key}: {fresh[key]:.3f} "
              f"(ledger {ledger[key]:.3f}, ratio {ratio:.2f})")
        if ratio < args.min_ratio:
            failures.append(key)
    fresh_payload = json.loads(fresh_path.read_text())
    metrics = fresh_payload.get("metrics", {})
    if "overhead_frac" in metrics:
        frac = float(metrics["overhead_frac"])
        dbytes = int(metrics.get("disabled_bytes", 0))
        status = "OK" if frac < args.max_overhead else "REGRESSION"
        print(f"  {status:>10}  overhead_frac: {frac:.4f} "
              f"(gate < {args.max_overhead})")
        if frac >= args.max_overhead:
            failures.append("overhead_frac")
        if dbytes != 0:
            print(f"  REGRESSION  disabled_bytes: {dbytes} (gate == 0)")
            failures.append("disabled_bytes")

    if failures:
        print(f"check_ledger: {len(failures)} gate failures: {failures}",
              file=sys.stderr)
        return 1
    print(f"check_ledger: {args.suite} within {args.min_ratio}x of ledger "
          f"({len(ledger)} baseline entries)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
