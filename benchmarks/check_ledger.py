"""Bench-ledger regression gate for the perf trajectory (ISSUE 7).

Compares a freshly emitted ``experiments/bench/BENCH_<suite>.json`` against
the committed trajectory under ``benchmarks/ledger/`` and fails when any
``rounds_per_sec`` entry drops below ``--min-ratio`` (default 0.3) of the
ledger value.  The threshold is deliberately loose: CI boxes are noisy and
the gate exists to catch order-of-magnitude regressions (an accidental
de-jit, a cache that stopped caching, a gather gone quadratic), not
percent-level drift.  Entries present in only one file are reported but
never fail the gate — the sweep grid may grow.

  PYTHONPATH=src python -m benchmarks.run --only cohort-store ...
  python benchmarks/check_ledger.py cohort-store [--min-ratio 0.3]

Exit 0 on pass, 1 on regression, 2 when either file is missing.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]
LEDGER = REPO / "benchmarks" / "ledger"
FRESH = REPO / "experiments" / "bench"


def _throughputs(payload: dict, prefix=()) -> dict:
    """Flatten metrics to {dotted.path: rounds_per_sec}."""
    out = {}
    node = payload.get("metrics", payload)
    stack = [(prefix, node)]
    while stack:
        path, cur = stack.pop()
        if not isinstance(cur, dict):
            continue
        for key, val in cur.items():
            if key == "rounds_per_sec" and isinstance(val, (int, float)):
                out[".".join(path)] = float(val)
            elif isinstance(val, dict):
                stack.append((path + (str(key),), val))
    return out


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("suite", help="suite name, e.g. cohort-store")
    ap.add_argument("--min-ratio", type=float, default=0.3,
                    help="fail when fresh rounds/sec < min_ratio * ledger")
    ap.add_argument("--fresh", default="",
                    help="override the fresh BENCH json path")
    args = ap.parse_args()

    ledger_path = LEDGER / f"BENCH_{args.suite}.json"
    fresh_path = Path(args.fresh) if args.fresh else (
        FRESH / f"BENCH_{args.suite}.json")
    for p, what in [(ledger_path, "committed ledger"), (fresh_path, "fresh run")]:
        if not p.exists():
            print(f"check_ledger: missing {what}: {p}", file=sys.stderr)
            return 2

    ledger = _throughputs(json.loads(ledger_path.read_text()))
    fresh = _throughputs(json.loads(fresh_path.read_text()))
    failures = []
    for key in sorted(set(ledger) | set(fresh)):
        if key not in ledger:
            print(f"  new entry (no ledger baseline): {key} "
                  f"{fresh[key]:.3f} r/s")
            continue
        if key not in fresh:
            print(f"  ledger entry absent from fresh run: {key}")
            continue
        ratio = fresh[key] / ledger[key] if ledger[key] else float("inf")
        status = "OK" if ratio >= args.min_ratio else "REGRESSION"
        print(f"  {status:>10}  {key}: {fresh[key]:.3f} r/s "
              f"(ledger {ledger[key]:.3f}, ratio {ratio:.2f})")
        if ratio < args.min_ratio:
            failures.append(key)
    if failures:
        print(f"check_ledger: {len(failures)} entries below "
              f"{args.min_ratio}x the committed trajectory: {failures}",
              file=sys.stderr)
        return 1
    print(f"check_ledger: {args.suite} within {args.min_ratio}x of ledger "
          f"({len(ledger)} baseline entries)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
