"""Benchmark harness - one benchmark per paper table/figure + the kernel
microbenches and the roofline summary.

  PYTHONPATH=src python -m benchmarks.run                 # everything (CPU-sized)
  PYTHONPATH=src python -m benchmarks.run --only table2   # one table
  PYTHONPATH=src python -m benchmarks.run --rounds 30     # bigger federation

Mapping to the paper (Sen & Mohan 2025):
  table1   per-round computation cost across methods (Table I analog:
           measured wall-clock per round, same model/partition for all)
  table2   best personalized accuracy, Dirichlet + pathological partitions
           (Table II analog on synthetic class-conditional images)
  table3   personalization-component ablation (Table III)
  table4   rho / lambda sensitivity (Table IV)
  figures  round-wise loss/accuracy histories (Figs. 2-4) -> JSON
  kernels  pfedsop_update / flash_gqa / rmsnorm microbench (interpret mode
           on CPU: validates + times the kernel bodies; TPU wall-times come
           from the roofline terms, not this box)
  engine   federation-engine throughput: rounds/sec for the vmap vs the
           shard_map backend across federation sizes (DESIGN.md §3; on a
           1-device box both run the same program - run under
           XLA_FLAGS=--xla_force_host_platform_device_count=N to see the
           multi-shard split)
  pfedsop-update  round-start-update impl shootout (DESIGN.md §9):
           rounds/sec for the pytree reference vs the fused Pallas kernel
           under both backends, with a per-backend parity assertion;
           --interpret forces the interpreter kernel (automatic off-TPU)
  async-engine  simulated wall-clock to a fixed target accuracy, sync vs
           async (DESIGN.md §10): heterogeneous client speeds (lognormal)
           + 30% availability; the bulk-synchronous server waits for
           stragglers while the async driver dispatches to online clients
           and applies FedBuff-style staleness-weighted buffered updates.
           Asserts async reaches the target in less simulated time AND
           that the staleness-weighted pFedSOP path still matches the
           fused-kernel dispatch (--interpret / automatic off-TPU)
  cohort-store  fleet-scale store sweep (DESIGN.md §12): rounds/sec and
           host<->device bytes moved vs fleet size K per store kind
           (device / host / mmap / LRU-cached host), K' fixed at 64,
           K = 10^3..10^5, with a bitwise parity assertion against the
           all-on-device baseline at the smallest K
  multipod-engine  mesh-engine shootout (DESIGN.md §11): rounds/sec and
           simulated time-to-target across {vmap, 1-D shard_map,
           multi-pod (2,2,2) mesh} x {sync, async}, asserting bitwise
           cross-backend history parity and model-sharded-kernel vs
           reference drift; needs 8 devices (CI forces host devices)
  model-fwd model-zoo forward tokens/sec per kernel impl x config
           (DESIGN.md §9, ``ModelConfig.kernel_impl``): reference vs
           kernel_interpret on a sliding-window (gemma3) and a
           full-attention (granite) reduced config, with a max-abs-drift
           assertion and a window-pruned flash_gqa grid-shape check
  roofline summary table from experiments/dryrun/*.json artifacts

Output: CSV lines ``name,us_per_call,derived`` + a human table; artifacts
under experiments/bench/.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.resnet_cifar import SMALL_CNN
from repro.core import baselines as bl
from repro.core.pfedsop import PFedSOPConfig
from repro.data import (
    FederatedData,
    dirichlet_partition,
    make_class_conditional_images,
    pathological_partition,
)
from repro.fl import (
    AsyncConfig,
    AsyncFederation,
    AvailabilityConfig,
    ClientAvailability,
    Federation,
    FLRunConfig,
)
from repro.fl.runtime import masked_accuracy
from repro.models import cnn
from repro.obs import ObsConfig

OUT = Path(__file__).resolve().parents[1] / "experiments" / "bench"

# --trace-dir/--obs-level (main) land here; benches that support tracing
# derive a per-run subdir with _obs_for so fingerprints never collide
OBS_CFG: dict = {}


def _obs_for(tag: str):
    """Per-bench-run ObsConfig under the harness --trace-dir (or None)."""
    if not OBS_CFG.get("trace_dir"):
        return None
    return ObsConfig(
        trace_dir=str(Path(OBS_CFG["trace_dir"]) / tag.replace("/", "_")),
        level=OBS_CFG.get("level", "phase"), quiet=True)

CFG = SMALL_CNN
METHOD_LIST = ["fedavg", "fedprox", "fedavg_ft", "fedprox_ft", "ditto",
               "fedrep", "local", "pfedsop"]


def _build(name, lr=0.05, rho=1.0, lam=1.0, use_pc=True, eta1=1.0):
    # eta1 (personalization lr) tuned per the paper's protocol (Sec. V-B4:
    # grid over lr per method); probe artifacts:
    # experiments/bench/pfedsop_eta1_tuning.json / pfedsop_tuned_compare.json
    if name == "pfedsop":
        return bl.PFedSOP(cfg=PFedSOPConfig(eta1=eta1, eta2=lr, rho=rho, lam=lam,
                                            use_pc=use_pc))
    if name == "fedrep":
        return bl.FedRep(lr=lr, head_predicate=lambda p: "fc_" in p)
    return bl.METHODS[name](lr=lr)


def _data(partition, seed=0, samples=3000, classes=10, clients=10):
    images, labels = make_class_conditional_images(samples, classes,
                                                   CFG.cnn_image_size, seed=seed)
    if partition == "dirichlet":
        parts = dirichlet_partition(labels, clients, 0.07, seed=seed)
    else:
        parts = pathological_partition(labels, clients, samples // (2 * clients),
                                       seed=seed)
    return FederatedData.from_partition(images, labels, parts, seed=seed)


def _run(method, data, rounds, seed=0, clients=10, backend="vmap",
         participation=0.4, update_impl="", obs=None):
    loss = lambda p, b: cnn.loss_fn(p, CFG, b)
    acc = masked_accuracy(lambda p, t: cnn.apply(p, CFG, t["images"]))
    params = cnn.init_params(jax.random.PRNGKey(seed), CFG)
    run_cfg = FLRunConfig(n_clients=clients, participation=participation,
                          rounds=rounds, batch=25, seed=seed, backend=backend,
                          update_impl=update_impl, obs=obs)
    fed = Federation(method, loss, acc, params, data, run_cfg)
    hist = fed.run()
    if fed.obs.final_metrics is not None:
        # surfaced into the suite's BENCH_*.json via the bench return value
        hist["obs_metrics"] = fed.obs.final_metrics
    return hist


# ---------------------------------------------------------------------------


def bench_table1(rounds):
    """Per-round wall time per method (Table I analog)."""
    print("\n== table1: per-round computation cost ==")
    data = _data("dirichlet")
    rows = []
    for name in METHOD_LIST:
        h = _run(_build(name), data, max(3, rounds // 3))
        t = float(np.mean(h["round_time"][1:]))  # skip compile round
        rows.append((name, t))
        print(f"bench,table1/{name},{t*1e6:.0f},s_per_round={t:.3f}")
    base = dict(rows)["fedavg"]
    print(f"{'method':>12} {'s/round':>8} {'vs fedavg':>9}")
    for n, t in rows:
        print(f"{n:>12} {t:>8.3f} {t/base:>8.2f}x")
    return {n: t for n, t in rows}


def bench_table2(rounds):
    """Best personalized accuracy on both partitions (Table II analog)."""
    print("\n== table2: best accuracy, both heterogeneous settings ==")
    out = {}
    for partition in ["dirichlet", "pathological"]:
        data = _data(partition)
        out[partition] = {}
        for name in METHOD_LIST:
            h = _run(_build(name), data, rounds)
            out[partition][name] = h["mean_best_acc"]
            print(f"bench,table2/{partition}/{name},"
                  f"{np.mean(h['round_time'][1:])*1e6:.0f},"
                  f"best_acc={h['mean_best_acc']:.4f}")
    print(f"{'method':>12} {'dirichlet':>10} {'pathological':>13}")
    for name in METHOD_LIST:
        print(f"{name:>12} {out['dirichlet'][name]:>10.4f} "
              f"{out['pathological'][name]:>13.4f}")
    best = max(out["dirichlet"], key=out["dirichlet"].get)
    print(f"--> best (dirichlet): {best}")
    return out


def bench_table3(rounds):
    """PC ablation (Table III)."""
    print("\n== table3: personalization component ablation ==")
    data = _data("dirichlet")
    out = {}
    for tag, use_pc in [("with_pc", True), ("without_pc", False)]:
        h = _run(_build("pfedsop", use_pc=use_pc), data, rounds)
        out[tag] = h["mean_best_acc"]
        print(f"bench,table3/{tag},0,best_acc={h['mean_best_acc']:.4f}")
    print(f"with PC {out['with_pc']:.4f} vs without {out['without_pc']:.4f}")
    return out


def bench_table4(rounds):
    """rho / lambda sensitivity (Table IV)."""
    print("\n== table4: rho / lambda sensitivity ==")
    data = _data("dirichlet")
    out = {"rho": {}, "lam": {}}
    for rho in [1.0, 0.1, 0.01]:
        h = _run(_build("pfedsop", rho=rho), data, rounds)
        out["rho"][rho] = h["mean_best_acc"]
        print(f"bench,table4/rho={rho},0,best_acc={h['mean_best_acc']:.4f}")
    for lam in [5.0, 1.0, 0.5]:
        h = _run(_build("pfedsop", lam=lam), data, rounds)
        out["lam"][lam] = h["mean_best_acc"]
        print(f"bench,table4/lam={lam},0,best_acc={h['mean_best_acc']:.4f}")
    return out


def bench_figures(rounds):
    """Round-wise loss/acc histories (Figs. 2-4 analog) -> JSON artifact."""
    print("\n== figures: round-wise curves ==")
    out = {}
    for partition in ["dirichlet", "pathological"]:
        data = _data(partition)
        out[partition] = {}
        for name in ["fedavg", "fedavg_ft", "ditto", "pfedsop"]:
            h = _run(_build(name), data, rounds)
            out[partition][name] = {"loss": h["loss"], "acc": h["acc"]}
            print(f"bench,figures/{partition}/{name},0,"
                  f"final_loss={h['loss'][-1]:.4f}")
    return out


def bench_kernels():
    """Kernel microbench (interpret mode: correctness-path timing only)."""
    print("\n== kernels: microbench (interpret=True on CPU) ==")
    from repro.kernels.pfedsop_update.ops import pfedsop_update
    from repro.kernels.flash_gqa.kernel import flash_gqa_pallas
    from repro.kernels.rmsnorm.ops import rmsnorm

    out = {}

    def timeit(name, fn, *a, n=5, **kw):
        fn(*a, **kw)  # compile
        t0 = time.perf_counter()
        for _ in range(n):
            r = fn(*a, **kw)
        jax.block_until_ready(r)
        us = (time.perf_counter() - t0) / n * 1e6
        out[name] = us
        print(f"bench,kernels/{name},{us:.0f},interpret=True")
        return us

    k = jax.random.PRNGKey(0)
    n = 1 << 16
    x, di, dg = (jax.random.normal(jax.random.fold_in(k, i), (n,)) for i in range(3))
    timeit("pfedsop_update_64k", pfedsop_update, x, di, dg, interpret=True)

    q = jax.random.normal(k, (1, 4, 128, 64))
    kk = jax.random.normal(k, (1, 2, 128, 64))
    v = jax.random.normal(k, (1, 2, 128, 64))
    timeit("flash_gqa_128", flash_gqa_pallas, q, kk, v, bq=64, bk=64, interpret=True)

    xx = jax.random.normal(k, (256, 512))
    ss = jnp.zeros((512,))
    timeit("rmsnorm_256x512", rmsnorm, xx, ss, interpret=True)
    return out


def bench_engine(rounds):
    """Federation-engine throughput: rounds/sec per backend x federation size.

    The per-round client phase is the scaling axis the engine shards
    (ISSUE: second-order FL wins by cutting rounds, so each round must scale
    across devices at realistic federation sizes).  Equal-seed backends run
    the same sampled rounds, so rounds/sec is directly comparable.
    """
    print("\n== engine: rounds/sec, vmap vs shard_map ==")
    n_dev = len(jax.devices())
    out = {}
    r = max(3, rounds // 3)
    # participation 0.5 -> K' = 4, 8, 16: power-of-two shard counts, so the
    # recommended 4-device run actually splits every federation size
    for clients in [8, 16, 32]:
        data = _data("dirichlet", clients=clients, samples=200 * clients)
        out[clients] = {}
        for backend in ["vmap", "shard_map"]:
            h = _run(_build("pfedsop"), data, r, clients=clients,
                     backend=backend, participation=0.5)
            t = float(np.mean(h["round_time"][1:]))  # skip compile round
            rps = 1.0 / max(t, 1e-9)
            out[clients][backend] = {
                "rounds_per_sec": rps,
                "shards": h["engine"].get("shards", 1),
            }
            print(f"bench,engine/{backend}/k{clients},{t*1e6:.0f},"
                  f"rounds_per_sec={rps:.3f},shards={h['engine'].get('shards', 1)}")
    print(f"({n_dev} local device(s))")
    print(f"{'clients':>8} {'vmap r/s':>9} {'shard_map r/s':>14} {'shards':>7}")
    for clients, row in out.items():
        print(f"{clients:>8} {row['vmap']['rounds_per_sec']:>9.3f} "
              f"{row['shard_map']['rounds_per_sec']:>14.3f} "
              f"{row['shard_map']['shards']:>7}")
    return out


def bench_pfedsop_update(rounds, interpret=False):
    """Round-start-update impl shootout: rounds/sec, reference vs fused
    kernel (DESIGN.md §9), under both engine backends.

    On CPU (or with --interpret) the kernel impl runs the Pallas
    interpreter — a correctness-path timing that keeps the bench runnable
    in CI; the honest kernel wall-time needs a TPU, where the same flag
    resolves to the compiled Mosaic kernel.  Parity (max |loss diff| vs
    the reference history on the same seed) is checked per backend so a
    broken kernel path fails loudly here, not just in the test suite.
    """
    print("\n== pfedsop-update: rounds/sec per impl x backend ==")
    kernel_impl = ("kernel_interpret"
                   if interpret or jax.default_backend() != "tpu" else "kernel")
    data = _data("dirichlet", clients=8, samples=1600)
    r = max(3, rounds // 3)
    out = {"kernel_impl": kernel_impl, "backends": {}}
    for backend in ["vmap", "shard_map"]:
        out["backends"][backend] = {}
        ref_hist = None
        for impl in ["reference", kernel_impl]:
            h = _run(_build("pfedsop"), data, r, clients=8, backend=backend,
                     participation=0.5, update_impl=impl)
            t = float(np.mean(h["round_time"][1:]))  # skip compile round
            rps = 1.0 / max(t, 1e-9)
            if impl == "reference":
                ref_hist = h
                drift = 0.0
            else:
                drift = float(np.max(np.abs(np.asarray(h["loss"])
                                            - np.asarray(ref_hist["loss"]))))
                assert drift < 1e-4, (
                    f"kernel impl diverged from reference under {backend}: "
                    f"max |loss diff| = {drift}")
            out["backends"][backend][impl] = {
                "rounds_per_sec": rps, "max_loss_drift_vs_reference": drift,
            }
            print(f"bench,pfedsop-update/{backend}/{impl},{t*1e6:.0f},"
                  f"rounds_per_sec={rps:.3f},drift={drift:.2e}")
    print(f"{'backend':>10} {'reference r/s':>14} {kernel_impl + ' r/s':>20}")
    for backend, row in out["backends"].items():
        print(f"{backend:>10} {row['reference']['rounds_per_sec']:>14.3f} "
              f"{row[kernel_impl]['rounds_per_sec']:>20.3f}")
    return out


def bench_async_engine(rounds, interpret=False):
    """Simulated wall-clock to target accuracy, sync vs async (DESIGN.md §10).

    The scenario the async subsystem exists for: lognormal per-client
    speeds + 30% availability.  The bulk-synchronous server samples
    obliviously and waits for every straggler to come online and finish
    (its simulated clock is ``ClientAvailability.sync_round_duration``);
    the async driver dispatches only to online clients and applies a
    staleness-weighted server update every ``buffer_size`` uploads.  Both
    drivers burn the same total upload budget, so simulated
    time-to-accuracy is the honest comparison — and the async win is
    asserted, not just reported.  A second async run forces the §9
    fused-kernel dispatch (interpret off-TPU) and asserts parity with the
    reference history: the staleness-weighted path must keep dispatching
    through ``pfedsop_update``.
    """
    print("\n== async-engine: simulated wall-clock to target accuracy ==")
    kernel_impl = ("kernel_interpret"
                   if interpret or jax.default_backend() != "tpu" else "kernel")
    clients, participation = 16, 0.5  # K' = 8
    buffer_size = 4
    data = _data("dirichlet", clients=clients, samples=200 * clients)
    loss = lambda p, b: cnn.loss_fn(p, CFG, b)
    acc = masked_accuracy(lambda p, t: cnn.apply(p, CFG, t["images"]))
    params = cnn.init_params(jax.random.PRNGKey(0), CFG)
    avail = AvailabilityConfig(speed="lognormal", sigma=1.0,
                               availability=0.3, mean_on=4.0)
    r = max(6, rounds)
    kprime = int(round(participation * clients))

    def _cfg(n_rounds, update_impl=""):
        return FLRunConfig(n_clients=clients, participation=participation,
                           rounds=n_rounds, batch=25, seed=0,
                           update_impl=update_impl)

    method = _build("pfedsop")
    model = ClientAvailability(avail, clients, 0)
    h_sync = Federation(method, loss, acc, params, data, _cfg(r),
                        availability=model).run()
    # same upload budget: r sync rounds x K' uploads == async versions x B
    async_rounds = r * kprime // buffer_size
    acfg = AsyncConfig(buffer_size=buffer_size, concurrency=kprime,
                       availability=avail)
    h_async = {}
    for impl in ["reference", kernel_impl]:
        h_async[impl] = AsyncFederation(method, loss, acc, params, data,
                                        _cfg(async_rounds, impl), acfg).run()
    drift = float(np.max(np.abs(np.asarray(h_async["reference"]["loss"])
                                - np.asarray(h_async[kernel_impl]["loss"]))))
    # fp32 reduction-order tolerance, wider than the pfedsop-update bench:
    # the async run accumulates ~2x the server updates of a sync round
    # budget, so per-round 1e-5-scale reduction noise compounds further
    assert drift < 1e-3, (
        f"staleness-weighted kernel dispatch diverged from reference: {drift}")

    # time at which the running-best cohort accuracy first clears the target
    def time_to(hist, target):
        best = np.maximum.accumulate(hist["acc"])
        hit = np.nonzero(best >= target)[0]
        return float(hist["sim_time"][hit[0]]) if len(hit) else None

    target = 0.8 * max(h_sync["acc"])
    t_sync = time_to(h_sync, target)
    t_async = time_to(h_async["reference"], target)
    assert t_async is not None, (
        f"async never reached target acc {target:.4f} "
        f"(best {max(h_async['reference']['acc']):.4f})")
    assert t_sync is None or t_async < t_sync, (
        f"async must reach target acc {target:.4f} in less simulated time: "
        f"async {t_async} vs sync {t_sync}")
    mean_tau = float(np.mean(h_async["reference"]["staleness"]))
    out = {
        "kernel_impl": kernel_impl,
        "clients": clients, "kprime": kprime, "buffer_size": buffer_size,
        "availability": avail.availability, "speed_sigma": avail.sigma,
        "target_acc": target,
        "sync": {"rounds": r, "sim_time_total": h_sync["sim_time"][-1],
                 "sim_time_to_target": t_sync,
                 "best_acc": float(max(h_sync["acc"]))},
        "async": {"versions": async_rounds,
                  "sim_time_total": h_async["reference"]["sim_time"][-1],
                  "sim_time_to_target": t_async,
                  "best_acc": float(max(h_async["reference"]["acc"])),
                  "mean_staleness": mean_tau},
        "max_loss_drift_vs_reference": drift,
    }
    print(f"bench,async-engine/sync,0,sim_t_to_target="
          f"{t_sync if t_sync is not None else float('inf'):.2f}")
    print(f"bench,async-engine/async,0,sim_t_to_target={t_async:.2f},"
          f"mean_tau={mean_tau:.2f},drift={drift:.2e}")
    print(f"{'driver':>8} {'sim_t_to_target':>16} {'sim_t_total':>12} {'best_acc':>9}")
    print(f"{'sync':>8} "
          f"{t_sync if t_sync is not None else float('inf'):>16.2f} "
          f"{h_sync['sim_time'][-1]:>12.2f} {max(h_sync['acc']):>9.4f}")
    print(f"{'async':>8} {t_async:>16.2f} "
          f"{h_async['reference']['sim_time'][-1]:>12.2f} "
          f"{max(h_async['reference']['acc']):>9.4f}")
    return out


def bench_multipod_engine(rounds, interpret=False):
    """Mesh-engine shootout (DESIGN.md §11): {vmap, 1-D shard_map,
    multi-pod mesh} x {sync, async} on a reduced (2,2,2) production mesh.

    Needs 8 local devices (CI runs it under
    XLA_FLAGS=--xla_force_host_platform_device_count=8); on a smaller box
    it reports what it can and marks the multi-pod column skipped.

    Reported: rounds/sec per backend x driver x output-sharding mode,
    plus simulated time-to-target-accuracy under heterogeneous
    availability (lognormal speeds + 30% availability).  Asserted, not
    just reported: (a) same impl, different backend => loss-history
    drift < 1e-4 (not bitwise with the interpret kernel on the hot
    path — see the inline comment at the assert; bitwise under
    update_impl="reference"); (b) reference vs kernel impl on the
    multi-pod mesh => drift < 1e-4 with the model-sharded batched
    kernel; (c) sharded output mode => BITWISE identical history to the
    same backend's replicated run (the §11 sharded-at-rest contract).

    On this CPU/interpret emulation the round is dominated by the
    interpret-mode pfedsop_update client phase (~85% of the round; the
    round-boundary all-gather is milliseconds), so sharded mode shows
    only a modest rounds/sec edge here — the collective it removes is
    an O(params * K') cross-pod gather that matters on real multi-pod
    hardware, not on forced host devices sharing one memory.
    """
    print("\n== multipod-engine: backend x driver, reduced (2,2,2) mesh ==")
    kernel_impl = ("kernel_interpret"
                   if interpret or jax.default_backend() != "tpu" else "kernel")
    n_dev = len(jax.devices())
    backends = [("vmap", ""), ("shard_map", "")]
    if n_dev >= 8:
        backends.append(("mesh", "pods:2x2x2"))
    else:
        print(f"bench,multipod-engine/skip,0,devices={n_dev}_of_8 "
              "(run under XLA_FLAGS=--xla_force_host_platform_device_count=8)")

    clients, participation = 8, 0.5  # K' = 4: divides pods(2) and devices
    r = max(4, rounds // 2)
    data = _data("dirichlet", clients=clients, samples=200 * clients)
    loss = lambda p, b: cnn.loss_fn(p, CFG, b)
    acc = masked_accuracy(lambda p, t: cnn.apply(p, CFG, t["images"]))
    params = cnn.init_params(jax.random.PRNGKey(0), CFG)
    avail = AvailabilityConfig(speed="lognormal", sigma=1.0,
                               availability=0.3, mean_on=4.0)
    kprime = int(round(participation * clients))
    buffer_size = kprime  # same server-update budget across drivers

    def _cfg(backend, mesh, update_impl, driver, output_sharding="replicated"):
        return FLRunConfig(
            n_clients=clients, participation=participation,
            rounds=r, batch=25, seed=0, backend=backend,
            mesh=mesh, update_impl=update_impl,
            output_sharding=output_sharding,
            obs=_obs_for(f"multipod/{backend}/{driver}/{update_impl}"))

    def time_to(hist, target):
        best = np.maximum.accumulate(hist["acc"])
        hit = np.nonzero(best >= target)[0]
        return float(hist["sim_time"][hit[0]]) if len(hit) else None

    out = {"kernel_impl": kernel_impl, "devices": n_dev,
           "backends": {}, "skipped_multipod": n_dev < 8}
    ref_hist = {}  # driver -> reference loss history (backend-invariant)
    for backend, mesh in backends:
        row = {}
        for driver in ["sync", "async"]:
            method = _build("pfedsop")
            for impl in ([kernel_impl, "reference"]
                         if backend == "mesh" else [kernel_impl]):
                cfg = _cfg(backend, mesh, impl, driver)
                if driver == "sync":
                    fed = Federation(method, loss, acc, params, data, cfg,
                                     availability=ClientAvailability(
                                         avail, clients, 0))
                else:
                    fed = AsyncFederation(
                        method, loss, acc, params, data, cfg,
                        AsyncConfig(buffer_size=buffer_size,
                                    concurrency=kprime, availability=avail))
                h = fed.run()
                if impl == "reference":
                    # multi-pod kernel parity: model-sharded kernel vs the
                    # pytree reference (fp32 reduction-order tolerance)
                    drift = float(np.max(np.abs(
                        np.asarray(h["loss"])
                        - np.asarray(row[driver]["loss"]))))
                    assert drift < 1e-4, (
                        f"model-sharded kernel diverged from reference "
                        f"({driver}): {drift}")
                    row[driver]["kernel_vs_reference_drift"] = drift
                    continue
                t = float(np.mean(h["round_time"][1:]))
                target = 0.8 * max(h["acc"])
                row[driver] = {
                    "rounds_per_sec": 1.0 / max(t, 1e-9),
                    "sim_time_to_target": time_to(h, target),
                    "sim_time_total": h["sim_time"][-1],
                    "loss": h["loss"],
                }
                if fed.obs.final_metrics is not None:
                    row[driver]["obs_metrics"] = fed.obs.final_metrics
                # same impl, any backend: tight history parity (§11).  Not
                # bitwise: XLA:CPU fuses the interpret-mode pfedsop_update
                # HLO differently inside the vmap-batched round program vs
                # the per-shard shard_map body (the kernel itself is bitwise
                # batch-invariant in isolation), so once re-participating
                # clients personalize (round 2+) uploads drift ~1e-6.  With
                # update_impl="reference" all backends ARE bitwise equal.
                # The bitwise contract this suite enforces is sharded vs
                # replicated output mode on the SAME backend, below.
                if driver not in ref_hist:
                    ref_hist[driver] = h["loss"]
                else:
                    xdrift = float(np.max(np.abs(
                        np.asarray(ref_hist[driver]) - np.asarray(h["loss"]))))
                    assert xdrift < 1e-4, (
                        f"{backend}/{driver}: loss history diverged across "
                        f"backends beyond fp tolerance ({xdrift}; "
                        "replicated-output contract, DESIGN.md §11)")
                print(f"bench,multipod-engine/{backend}/{driver},{t*1e6:.0f},"
                      f"rounds_per_sec={1.0/max(t,1e-9):.3f},"
                      f"sim_t_total={h['sim_time'][-1]:.2f}")
        # sharded-at-rest round loop (§11 output sharding): engine outputs
        # keep the client sharding, Eq. 13 aggregation runs inside the
        # sharded program — the round-boundary all-gather disappears.
        # Histories must stay BITWISE equal to the replicated runs above.
        for driver in ([] if backend == "vmap" else ["sync", "async"]):
            method = _build("pfedsop")
            cfg = _cfg(backend, mesh, kernel_impl, f"{driver}-sharded",
                       output_sharding="sharded")
            if driver == "sync":
                fed = Federation(method, loss, acc, params, data, cfg,
                                 availability=ClientAvailability(
                                     avail, clients, 0))
            else:
                fed = AsyncFederation(
                    method, loss, acc, params, data, cfg,
                    AsyncConfig(buffer_size=buffer_size,
                                concurrency=kprime, availability=avail))
            h = fed.run()
            assert row[driver]["loss"] == h["loss"], (
                f"{backend}/{driver}: sharded-output loss history must be "
                "BITWISE identical to replicated mode (DESIGN.md §11)")
            t = float(np.mean(h["round_time"][1:]))
            row[f"{driver}_sharded"] = {
                "rounds_per_sec": 1.0 / max(t, 1e-9),
                "sim_time_total": h["sim_time"][-1],
            }
            print(f"bench,multipod-engine/{backend}/{driver}-sharded,"
                  f"{t*1e6:.0f},rounds_per_sec={1.0/max(t,1e-9):.3f},"
                  f"sim_t_total={h['sim_time'][-1]:.2f}")
        out["backends"][backend] = {
            d: {key: v for key, v in row[d].items() if key != "loss"}
            for d in row
        }
    print(f"{'backend':>10} {'sync r/s':>9} {'async r/s':>10} "
          f"{'sync-sh r/s':>12} {'async-sh r/s':>13}")
    for backend, row in out["backends"].items():
        sh = row.get("sync_sharded", {}).get("rounds_per_sec")
        ash = row.get("async_sharded", {}).get("rounds_per_sec")
        print(f"{backend:>10} {row['sync']['rounds_per_sec']:>9.3f} "
              f"{row['async']['rounds_per_sec']:>10.3f} "
              f"{sh if sh is not None else float('nan'):>12.3f} "
              f"{ash if ash is not None else float('nan'):>13.3f}")
    return out


def bench_cohort_store(rounds):
    """Fleet-scale cohort-store sweep (DESIGN.md §12): rounds/sec and
    host<->device bytes moved vs fleet size K per store kind.

    The store's claim is that K is a *throughput* knob, not a device-memory
    limit: per-client state rests on host numpy (``host``) or disk-backed
    memmap (``mmap``) and only the round's K' participants are gathered to
    device.  The sweep holds K' fixed at 64 and scales K across
    10^3..10^5 — device memory stays flat while at-rest bytes scale with
    K.  At the smallest K every kind (plus an LRU-cached host store) runs
    and the loss histories + final client states are asserted BITWISE
    identical to the all-on-device baseline; the larger sizes run only the
    kinds whose at-rest tier fits the CI budget (RAM at 10^4, disk at
    10^5 — capped below the ISSUE's 10^6 upper bound, which the mmap
    store reaches with the same command and more disk/time; the cap is
    printed, not silent).
    """
    print("\n== cohort-store: rounds/sec + bytes moved vs fleet size ==")
    from repro.fl import StoreConfig

    # tiny CNN so at-rest state is ~KB/client and the 10^5 sweep fits CI
    cfg = CFG.replace(name="fleet-cnn", cnn_channels=(4,), cnn_image_size=8,
                      n_classes=4)
    loss = lambda p, b: cnn.loss_fn(p, cfg, b)
    acc = masked_accuracy(lambda p, t: cnn.apply(p, cfg, t["images"]))
    params = cnn.init_params(jax.random.PRNGKey(0), cfg)
    kprime, r = 64, max(3, rounds // 3)

    def fleet_data(k, seed=0):
        # shared tiny sample bank, 5 overlapping samples per client: the
        # bench measures state movement, so per-client data stays O(1)
        images, labels = make_class_conditional_images(512, cfg.n_classes,
                                                       cfg.cnn_image_size,
                                                       seed=seed)
        parts = [np.arange((5 * i) % 500, (5 * i) % 500 + 5) for i in range(k)]
        return FederatedData.from_partition(images, labels, parts, seed=seed)

    def run_one(data, k, store):
        run_cfg = FLRunConfig(n_clients=k, participation=kprime / k, rounds=r,
                              batch=4, local_iters=1, seed=0, store=store)
        fed = Federation(_build("pfedsop"), loss, acc, params, data, run_cfg)
        hist = fed.run()
        return fed, hist

    plans = {
        1_000: ["device", "host", "mmap", "host+cache"],
        10_000: ["host", "host+cache"],
        100_000: ["mmap"],
    }
    print("bench,cohort-store/cap,0,max_k=100000_of_issue_1e6 "
          "(mmap reaches 1e6 with more disk/time)")
    out = {"kprime": kprime, "rounds": r, "sizes": {}}
    for k, kinds in plans.items():
        data = fleet_data(k)
        out["sizes"][k] = {}
        baseline = None  # (hist, final states) of the device store
        for tag in kinds:
            store = (StoreConfig(kind="host", cache_clients=4 * kprime)
                     if tag == "host+cache" else tag)
            fed, h = run_one(data, k, store)
            t = float(np.mean(h["round_time"][1:]))  # skip compile round
            stats = fed.store.stats()
            hits = stats["cache_hits"] + stats["cache_misses"]
            row = {
                "rounds_per_sec": 1.0 / max(t, 1e-9),
                "h2d_bytes": stats["h2d_bytes"],
                "d2h_bytes": stats["d2h_bytes"],
                "at_rest_bytes": getattr(fed.store, "at_rest_bytes", 0),
                "cache_hit_rate": stats["cache_hits"] / hits if hits else None,
            }
            out["sizes"][k][tag] = row
            print(f"bench,cohort-store/{tag}/k{k},{t*1e6:.0f},"
                  f"rounds_per_sec={row['rounds_per_sec']:.3f},"
                  f"h2d_mb={stats['h2d_bytes']/1e6:.1f},"
                  f"d2h_mb={stats['d2h_bytes']/1e6:.1f}")
            # bitwise parity vs the all-on-device baseline (the §12
            # contract), checked where the device store itself runs
            final = jax.tree.leaves(jax.tree.map(np.asarray, fed.client_states))
            if baseline is None:
                baseline = (h, final)
            else:
                assert h["loss"] == baseline[0]["loss"], (
                    f"{tag}/k{k}: loss history must be bitwise identical "
                    "to the device store")
                assert all(np.array_equal(a, b)
                           for a, b in zip(baseline[1], final)), (
                    f"{tag}/k{k}: final client states must be bitwise "
                    "identical to the device store")
    print(f"{'K':>8} {'store':>11} {'r/s':>7} {'h2d MB':>7} {'at-rest MB':>11}")
    for k, row in out["sizes"].items():
        for tag, m in row.items():
            print(f"{k:>8} {tag:>11} {m['rounds_per_sec']:>7.2f} "
                  f"{m['h2d_bytes']/1e6:>7.1f} {m['at_rest_bytes']/1e6:>11.1f}")
    return out


def bench_obs_overhead(rounds):
    """Observability overhead gate (DESIGN.md §13).

    Runs the same federation with observability off and with phase-level
    tracing + metrics on, and asserts the §13 contract in both directions:

    - **disabled is free**: the off run holds the shared NOOP facade and
      the would-be trace directory is never created — 0 bytes written;
    - **enabled changes wall-clock only**: every history series except
      ``round_time`` (and the attached ``obs_metrics``) is bitwise
      identical to the off run;
    - **enabled is cheap**: the per-round overhead fraction is recorded in
      the BENCH artifact, and ``benchmarks/check_ledger.py obs-overhead``
      gates it at <5% (the in-bench assert stays loose — CI boxes are
      noisy — the ledger gate is the enforcement point).
    """
    print("\n== obs-overhead: traced vs untraced, same seed ==")
    import shutil

    data = _data("dirichlet", clients=8, samples=1600)
    r = max(6, rounds)
    base = OUT / "obs_trace"
    off_dir, on_dir = base / "overhead_off", base / "overhead_on"
    shutil.rmtree(base, ignore_errors=True)

    h_off = _run(_build("pfedsop"), data, r, clients=8, participation=0.5)
    assert not off_dir.exists(), (
        "observability off must write 0 bytes, but the trace dir exists")
    h_on = _run(_build("pfedsop"), data, r, clients=8, participation=0.5,
                obs=ObsConfig(trace_dir=str(on_dir), level="phase",
                              quiet=True))
    for key in h_off:
        if key == "round_time":
            continue
        assert h_off[key] == h_on[key], (
            f"history[{key!r}] must be bitwise identical traced vs "
            "untraced (obs reads host numbers, never touches traced values)")

    t_off = float(np.mean(h_off["round_time"][1:]))  # skip compile round
    t_on = float(np.mean(h_on["round_time"][1:]))
    overhead = t_on / max(t_off, 1e-9) - 1.0
    trace_bytes = sum(f.stat().st_size for f in on_dir.rglob("*")
                      if f.is_file())
    out = {
        "rounds": r,
        "off": {"rounds_per_sec": 1.0 / max(t_off, 1e-9),
                "disabled_bytes": 0},
        "on": {"rounds_per_sec": 1.0 / max(t_on, 1e-9),
               "trace_bytes": trace_bytes,
               "obs_metrics": h_on.get("obs_metrics")},
        "overhead_frac": overhead,
        "disabled_bytes": 0,
    }
    print(f"bench,obs-overhead/off,{t_off*1e6:.0f},"
          f"rounds_per_sec={out['off']['rounds_per_sec']:.3f}")
    print(f"bench,obs-overhead/on,{t_on*1e6:.0f},"
          f"rounds_per_sec={out['on']['rounds_per_sec']:.3f},"
          f"overhead_frac={overhead:.4f},trace_kb={trace_bytes/1e3:.1f}")
    # loose in-bench sanity bound only (see docstring): a 2x slowdown
    # means the instrumentation landed on the traced path, not the host
    assert overhead < 1.0, (
        f"phase-level tracing more than doubled round time: {overhead:.2f}")
    return out


def bench_model_fwd():
    """Model-zoo forward throughput per kernel impl x config (DESIGN.md §9).

    The dominant per-round FLOPs of the federated LM path are the
    transformer forward/backward, so the model-level ``kernel_impl`` knob
    is benched end-to-end here: tokens/sec through ``transformer.forward``
    for the reference path vs the Pallas kernel path (interpret mode on
    CPU — correctness-path timing; honest kernel wall-times need a TPU).
    Two reduced configs, one with sliding-window layers (gemma3-1b, window
    capped so the window actually binds at bench seq-len) and one
    full-attention (granite-3-2b).  Asserts (a) max-abs hidden-state drift
    between impls and (b) that the window-pruned flash_gqa grid visits
    strictly fewer KV blocks than the unpruned grid — at the shape this
    bench runs AND at the production train_4k shape (grid-shape assertion,
    not timing).
    """
    print("\n== model-fwd: tokens/sec per kernel impl x config ==")
    from repro.configs import get_config
    from repro.kernels.flash_gqa.kernel import flash_gqa_grid
    from repro.models import transformer as tf

    b, s, iters = 2, 64, 3
    win = 16
    configs = []
    # sliding-window + qk-norm config: cap every window at `win` (the
    # long_500k machinery) and shrink attention blocks so the window is
    # smaller than the sequence at bench size
    g3 = get_config("gemma3-1b", reduced=True).replace(
        long_context_window=win, attn_q_block=win)
    configs.append(tf.apply_long_context(g3))
    configs.append(get_config("granite-3-2b", reduced=True))

    out = {}
    for cfg in configs:
        key = jax.random.PRNGKey(0)
        params = tf.init_params(key, cfg)
        batch = {"tokens": jax.random.randint(jax.random.fold_in(key, 1),
                                              (b, s), 0, cfg.vocab_size)}
        out[cfg.name] = {}
        hidden = {}
        for impl in ["reference", "kernel_interpret"]:
            c = cfg.replace(kernel_impl=impl)
            fwd = jax.jit(lambda p, bt, c=c: tf.forward(p, c, bt)[0])
            h = jax.block_until_ready(fwd(params, batch))  # compile
            t0 = time.perf_counter()
            for _ in range(iters):
                h = fwd(params, batch)
            jax.block_until_ready(h)
            dt = (time.perf_counter() - t0) / iters
            tps = b * s / max(dt, 1e-9)
            hidden[impl] = np.asarray(h, np.float32)
            out[cfg.name][impl] = {"tokens_per_sec": tps, "s_per_fwd": dt}
            print(f"bench,model-fwd/{cfg.name}/{impl},{dt*1e6:.0f},"
                  f"tokens_per_sec={tps:.0f}")
        drift = float(np.max(np.abs(hidden["reference"]
                                    - hidden["kernel_interpret"])))
        assert drift < 1e-4, (
            f"{cfg.name}: kernel impl drifted from reference: "
            f"max |hidden diff| = {drift}")
        out[cfg.name]["max_abs_drift"] = drift
        print(f"bench,model-fwd/{cfg.name}/drift,0,max_abs={drift:.2e}")

    # window-pruned grid: strictly fewer KV blocks than unpruned, at the
    # bench shape and at the production train_4k shape (gemma2 window 4096
    # at 32k prefill; gemma3 window 512 at 4k train)
    prune_cases = [
        ("bench", s, win, win, win),
        ("gemma3_train4k", 4096, 512, 512, 512),
        ("gemma2_prefill32k", 32768, 512, 512, 4096),
    ]
    out["pruned_grid"] = {}
    for tag, ss, bq, bk, w in prune_cases:
        nq_p, nk_p = flash_gqa_grid(ss, bq, bk, window=w, prune_window=True)
        nq_u, nk_u = flash_gqa_grid(ss, bq, bk, window=w, prune_window=False)
        assert nq_p == nq_u and nk_p < nk_u, (
            f"pruned grid must visit fewer KV blocks: {tag}: "
            f"pruned {(nq_p, nk_p)} vs unpruned {(nq_u, nk_u)}")
        out["pruned_grid"][tag] = {"pruned_nk": nk_p, "unpruned_nk": nk_u}
        print(f"bench,model-fwd/pruned-grid/{tag},0,"
              f"kv_blocks={nk_p}_of_{nk_u}")

    print(f"{'config':>16} {'ref tok/s':>10} {'kernel tok/s':>13} {'drift':>9}")
    for name, row in out.items():
        if name == "pruned_grid":
            continue
        print(f"{name:>16} {row['reference']['tokens_per_sec']:>10.0f} "
              f"{row['kernel_interpret']['tokens_per_sec']:>13.0f} "
              f"{row['max_abs_drift']:>9.2e}")
    return out


def bench_model_bwd():
    """Train-step (fwd+bwd) throughput per kernel impl x config, plus the
    dispatched attention backward (DESIGN.md §9, kernel ``flash_gqa_bwd``)
    benched at the ops level: fused flash backward vs the scan-of-VJPs
    reference on the same kernel forward.

    Like model-fwd this is correctness-path timing on CPU (interpret
    mode); the asymptotic claim is asserted structurally instead: at the
    production gemma3 train_4k shape the fused backward's two passes
    visit O(S·W) tiles (dq reuses the forward's pruned KV grid, dk/dv
    visits ceil((W+BK)/BQ)+1 q-blocks per k-block) while the scan VJP
    recomputes full-S attention per q-block — an O(S²) tile count.
    """
    print("\n== model-bwd: train-step tokens/sec per kernel impl x config ==")
    from repro.configs import get_config
    from repro.kernels.flash_gqa.kernel import (flash_gqa_bwd_grid,
                                                flash_gqa_grid)
    from repro.kernels.flash_gqa.ops import flash_gqa
    from repro.models import transformer as tf

    b, s, iters = 2, 64, 3
    win = 16
    g3 = get_config("gemma3-1b", reduced=True).replace(
        long_context_window=win, attn_q_block=win)
    configs = [tf.apply_long_context(g3),
               get_config("granite-3-2b", reduced=True)]

    out = {}
    for cfg in configs:
        key = jax.random.PRNGKey(0)
        params = tf.init_params(key, cfg)
        batch = {
            "tokens": jax.random.randint(jax.random.fold_in(key, 1), (b, s),
                                         0, cfg.vocab_size),
            "labels": jax.random.randint(jax.random.fold_in(key, 2), (b, s),
                                         0, cfg.vocab_size),
        }
        out[cfg.name] = {}
        results = {}
        for impl in ["reference", "kernel_interpret"]:
            c = cfg.replace(kernel_impl=impl)
            step = jax.jit(lambda p, bt, c=c: jax.value_and_grad(
                lambda pp: tf.lm_loss(pp, c, bt))(p))
            lv, g = jax.block_until_ready(step(params, batch))  # compile
            t0 = time.perf_counter()
            for _ in range(iters):
                lv, g = step(params, batch)
            jax.block_until_ready(g)
            dt = (time.perf_counter() - t0) / iters
            tps = b * s / max(dt, 1e-9)
            results[impl] = (float(lv), g)
            out[cfg.name][impl] = {"tokens_per_sec": tps, "s_per_step": dt}
            print(f"bench,model-bwd/{cfg.name}/{impl},{dt*1e6:.0f},"
                  f"tokens_per_sec={tps:.0f}")
        # kernel_interpret routes the backward through the fused flash
        # backward kernel (attention_fwd passes bwd=impl) — loss AND grads
        # must stay within fp32 reduction-order drift of the reference
        loss_drift = abs(results["kernel_interpret"][0]
                         - results["reference"][0])
        grad_drift = max(
            float(np.max(np.abs(np.asarray(a, np.float32)
                                - np.asarray(b_, np.float32))))
            for a, b_ in zip(jax.tree.leaves(results["kernel_interpret"][1]),
                             jax.tree.leaves(results["reference"][1])))
        assert loss_drift < 1e-4 and grad_drift < 5e-3, (
            f"{cfg.name}: fused backward drifted from reference: "
            f"loss {loss_drift:.2e}, grad {grad_drift:.2e}")
        out[cfg.name]["max_abs_grad_drift"] = grad_drift
        print(f"bench,model-bwd/{cfg.name}/drift,0,"
              f"loss={loss_drift:.2e},grad={grad_drift:.2e}")

    # ops-level backward shootout: same kernel forward, dispatched backward
    sb, ss, sd, sh, skv, swin = 1, 256, 32, 4, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (sb, ss, sh, sd), jnp.float32)
    k = jax.random.normal(ks[1], (sb, ss, skv, sd), jnp.float32)
    v = jax.random.normal(ks[2], (sb, ss, skv, sd), jnp.float32)
    out["attention_bwd"] = {}
    for bwd in ["reference", "kernel_interpret"]:
        grad = jax.jit(jax.grad(
            lambda q, k, v, bwd=bwd: jnp.sum(
                flash_gqa(q, k, v, window=swin, bq=64, bk=64, interpret=True,
                          bwd=bwd).astype(jnp.float32) ** 2),
            argnums=(0, 1, 2)))
        jax.block_until_ready(grad(q, k, v))  # compile
        t0 = time.perf_counter()
        for _ in range(iters):
            g = grad(q, k, v)
        jax.block_until_ready(g)
        dt = (time.perf_counter() - t0) / iters
        tps = sb * ss / max(dt, 1e-9)
        out["attention_bwd"][bwd] = {"tokens_per_sec": tps, "s_per_grad": dt}
        print(f"bench,model-bwd/attention-bwd/{bwd},{dt*1e6:.0f},"
              f"tokens_per_sec={tps:.0f}")

    # structural win at the production train_4k shape: fused backward tile
    # count is O(S·W), the scan VJP's recomputation is O(S²)
    out["bwd_grid"] = {}
    for tag, ts, bq, bk, w in [("bench", s, win, win, win),
                               ("gemma3_train4k", 4096, 512, 512, 512)]:
        nq_f, nk_f = flash_gqa_grid(ts, bq, bk, window=w, prune_window=False)
        nk_dq, nq_dkv = flash_gqa_bwd_grid(ts, bq, bk, window=w)
        fused_tiles = nq_f * nk_dq + nk_f * nq_dkv  # dq pass + dk/dv pass
        scan_tiles = 2 * nq_f * nk_f  # recomputed fwd + vjp, full S keys
        assert fused_tiles < scan_tiles, (
            f"fused backward must visit fewer tiles than the scan VJP: "
            f"{tag}: {fused_tiles} vs {scan_tiles}")
        out["bwd_grid"][tag] = {"fused_tiles": fused_tiles,
                                "scan_vjp_tiles": scan_tiles}
        print(f"bench,model-bwd/bwd-grid/{tag},0,"
              f"tiles={fused_tiles}_of_{scan_tiles}")

    print(f"{'config':>16} {'ref tok/s':>10} {'kernel tok/s':>13} {'drift':>9}")
    for name, row in out.items():
        if name in ("attention_bwd", "bwd_grid"):
            continue
        print(f"{name:>16} {row['reference']['tokens_per_sec']:>10.0f} "
              f"{row['kernel_interpret']['tokens_per_sec']:>13.0f} "
              f"{row['max_abs_grad_drift']:>9.2e}")
    return out


def bench_roofline():
    """Summarise the dry-run artifacts (§Roofline table)."""
    print("\n== roofline: dry-run artifact summary ==")
    art = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"
    rows = []
    for f in sorted(art.glob("*.json")):
        r = json.loads(f.read_text())
        rl = r.get("roofline", {})
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "variant": r.get("variant", "baseline"),
            "dominant": rl.get("dominant"),
            "compute_s": rl.get("compute_s"), "memory_s": rl.get("memory_s"),
            "collective_s": rl.get("collective_s"),
        })
        print(f"bench,roofline/{r['arch']}/{r['shape']}/{r['mesh']},0,"
              f"dominant={rl.get('dominant')}")
    print(f"({len(rows)} artifacts)")
    return rows


BENCHES = {
    "table1": bench_table1,
    "table2": bench_table2,
    "table3": bench_table3,
    "table4": bench_table4,
    "figures": bench_figures,
    "engine": bench_engine,
    "kernels": bench_kernels,
    "pfedsop-update": bench_pfedsop_update,
    "async-engine": bench_async_engine,
    "multipod-engine": bench_multipod_engine,
    "cohort-store": bench_cohort_store,
    "obs-overhead": bench_obs_overhead,
    "model-fwd": bench_model_fwd,
    "model-bwd": bench_model_bwd,
    "roofline": bench_roofline,
}


def emit_bench_json(suite: str, metrics, args) -> Path:
    """Write the machine-readable per-suite trajectory file.

    ``experiments/bench/BENCH_<suite>.json``: suite name, run config, the
    suite's metrics, and the commit timestamp *passed in* by the caller
    (CI passes ``git log -1 --format=%cI``) — never sampled from the wall
    clock, so re-running a commit produces an identical artifact and the
    perf trajectory stays attributable to commits.  Uploaded as a CI
    artifact by .github/workflows/ci.yml.
    """
    payload = {
        "suite": suite,
        "commit_timestamp": args.commit_ts,
        "config": {
            "rounds": args.rounds,
            "interpret": args.interpret,
            "devices": len(jax.devices()),
            "jax_backend": jax.default_backend(),
        },
        "metrics": metrics,
    }
    path = OUT / f"BENCH_{suite}.json"
    path.write_text(json.dumps(payload, indent=1, default=float))
    return path


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="+", choices=sorted(BENCHES), default=None)
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--interpret", action="store_true",
                    help="force the Pallas interpreter for kernel impls "
                         "(pfedsop-update / async-engine benches; automatic "
                         "off-TPU)")
    ap.add_argument("--commit-ts", default="",
                    help="commit timestamp (e.g. git log -1 --format=%%cI) "
                         "stamped into BENCH_<suite>.json; passed in, not "
                         "sampled, so artifacts are reproducible per commit")
    ap.add_argument("--trace-dir", default="",
                    help="trace supporting benches (multipod-engine) into "
                         "per-run subdirs here (DESIGN.md §13); summarize "
                         "with scripts/trace_report.py")
    ap.add_argument("--obs-level", choices=["round", "phase", "kernel"],
                    default="phase",
                    help="instrumentation depth for --trace-dir runs")
    args = ap.parse_args()
    if args.trace_dir:
        OBS_CFG.update(trace_dir=args.trace_dir, level=args.obs_level)

    OUT.mkdir(parents=True, exist_ok=True)
    names = args.only or list(BENCHES)
    results = {}
    t0 = time.time()
    for name in names:
        fn = BENCHES[name]
        if name in ("kernels", "model-fwd", "model-bwd", "roofline"):
            results[name] = fn()
        elif name in ("pfedsop-update", "async-engine", "multipod-engine"):
            results[name] = fn(args.rounds, interpret=args.interpret)
        else:
            results[name] = fn(args.rounds)
        # one trajectory artifact per suite, written as soon as the suite
        # finishes (partial runs still land their artifacts)
        print(f"wrote {emit_bench_json(name, results[name], args)}")
    (OUT / "results.json").write_text(json.dumps(results, indent=1, default=float))
    print(f"\nwrote experiments/bench/results.json ({time.time()-t0:.0f}s total)")


if __name__ == "__main__":
    main()
